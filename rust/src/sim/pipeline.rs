//! Network-level pipelined execution (paper §V-B: "our simulator employs
//! layer-wise pipelining").
//!
//! Each layer's ECU buffers its output spike train and immediately starts
//! the next time step, so layer `l` processes step `t` as soon as (a) it
//! finished step `t-1` and (b) layer `l-1` delivered step `t`. The
//! scheduling recurrence itself lives in [`crate::sim::engine`] — every
//! public run mode here (`run`, `run_recording`, `run_activity`,
//! `run_batched`) is a thin wrapper that pairs the unified [`Engine`] loop
//! with the right [`Workload`] and [`Probe`].
//!
//! Total inference latency is `finish[L-1][T-1]`; the bottleneck layer's
//! per-step cost dominates in steady state — the effect the paper's Table I
//! and Fig. 6 explore.

use crate::config::ExperimentConfig;
use crate::sim::batch_kernel::{run_sliced, selects_sliced, BatchKernel};
use crate::sim::costs::CostModel;
use crate::sim::engine::{
    ActivityWorkload, BatchDecodeProbe, BatchWorkload, Engine, NullProbe, Probe,
    SpikeTrainWorkload, TraceProbe, Workload,
};
use crate::sim::layer::{LayerSim, LayerWeights};
use crate::sim::stats::SimResult;
use crate::snn::{BitVec, Layer, NetDef, SpikeTrain};
use crate::util::rng::Rng;

/// A configured accelerator instance: one `LayerSim` per network layer,
/// plus the reusable scheduling engine (finish-time vector + ping-pong
/// spike buffers shared across runs).
///
/// ```
/// use snn_dse::config::{ExperimentConfig, HwConfig};
/// use snn_dse::sim::{random_spike_train, CostModel, NetworkSim};
/// use snn_dse::snn::table1_net;
/// use snn_dse::util::rng::Rng;
///
/// let net = table1_net("net1");
/// let cfg = ExperimentConfig::new(net.clone(), HwConfig::with_lhr(vec![4, 8, 8])).unwrap();
/// let mut sim = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
/// let input = random_spike_train(net.input_bits, net.t_steps, 0.1, &mut Rng::new(1));
/// let result = sim.run(&input);
/// // pipelining keeps total latency under the sum of per-layer times
/// assert!(result.total_cycles > 0);
/// assert!(result.total_cycles <= result.serial_cycles);
/// ```
pub struct NetworkSim {
    pub net: NetDef,
    pub layers: Vec<LayerSim>,
    clock_hz: f64,
    engine: Engine,
}

/// Per-sample outcome of a batched serving run
/// ([`NetworkSim::run_batched_timed`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchOutcome {
    /// Decoded class (population-coded argmax), if decodable.
    pub prediction: Option<usize>,
    /// Pipelined cycle at which the sample's last step left the final
    /// layer, measured from the start of the batch.
    pub completion_cycles: u64,
}

impl NetworkSim {
    /// Build with explicit weights (from `artifacts/`); `weights[i]`
    /// corresponds to the i-th *parametric* layer.
    pub fn new(cfg: &ExperimentConfig, mut weights: Vec<LayerWeights>, costs: CostModel) -> Self {
        let param = cfg.net.parametric_layers();
        assert_eq!(
            weights.len(),
            param.len(),
            "need one LayerWeights per parametric layer"
        );
        let mut weights_iter = {
            weights.reverse();
            weights
        };
        let mut layers = Vec::new();
        let mut k = 0usize; // parametric index
        for (i, layer) in cfg.net.layers.iter().enumerate() {
            let (lhr, blocks, w) = if layer.is_parametric() {
                let lhr = cfg.hw.lhr[k];
                let blocks = cfg.hw.mem_blocks.get(k).copied().unwrap_or(0);
                k += 1;
                (lhr, blocks, weights_iter.pop().unwrap())
            } else {
                (1, 0, LayerWeights::None)
            };
            layers.push(LayerSim::new(
                i,
                layer.clone(),
                lhr,
                blocks,
                cfg.hw.penc_width,
                cfg.net.beta,
                cfg.net.theta,
                w,
                costs.clone(),
            ));
        }
        NetworkSim {
            net: cfg.net.clone(),
            layers,
            clock_hz: cfg.hw.clock_hz,
            engine: Engine::new(),
        }
    }

    /// Build a cost-only instance for activity-driven runs: no weights or
    /// state buffers are allocated, only the cycle/resource bookkeeping.
    /// Calling `run`/`run_recording` on it will panic; use `run_activity`.
    pub fn cost_only(cfg: &ExperimentConfig, costs: CostModel) -> Self {
        let mut layers = Vec::new();
        let mut k = 0usize;
        for (i, layer) in cfg.net.layers.iter().enumerate() {
            let (lhr, blocks) = if layer.is_parametric() {
                let v = (cfg.hw.lhr[k], cfg.hw.mem_blocks.get(k).copied().unwrap_or(0));
                k += 1;
                v
            } else {
                (1, 0)
            };
            layers.push(LayerSim::new_cost_only(
                i,
                layer.clone(),
                lhr,
                blocks,
                cfg.hw.penc_width,
                costs.clone(),
            ));
        }
        NetworkSim {
            net: cfg.net.clone(),
            layers,
            clock_hz: cfg.hw.clock_hz,
            engine: Engine::new(),
        }
    }

    /// Build with random weights (DSE without trained artifacts). Weight
    /// scale is chosen so layers exhibit realistic firing rates.
    pub fn with_random_weights(cfg: &ExperimentConfig, seed: u64, costs: CostModel) -> Self {
        let mut rng = Rng::new(seed);
        let weights = cfg
            .net
            .parametric_layers()
            .iter()
            .map(|&i| random_weights(&cfg.net.layers[i], &mut rng))
            .collect();
        NetworkSim::new(cfg, weights, costs)
    }

    pub fn clock_hz(&self) -> f64 {
        self.clock_hz
    }

    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.reset();
        }
    }

    /// Drive the unified engine with an arbitrary workload/probe pair —
    /// the extension point every specialized run mode below builds on.
    pub fn run_engine<W: Workload, P: Probe>(
        &mut self,
        workload: &mut W,
        probe: &mut P,
    ) -> SimResult {
        let out_bits = self.net.layers.last().map(|l| l.output_bits()).unwrap_or(0);
        let NetworkSim { layers, engine, .. } = self;
        engine.run(layers, out_bits, workload, probe)
    }

    /// Functional run over a full input spike train; returns latency,
    /// per-layer stats, and the output spike accumulation.
    pub fn run(&mut self, input: &SpikeTrain) -> SimResult {
        let mut workload = SpikeTrainWorkload::new(input);
        let mut result = self.run_engine(&mut workload, &mut NullProbe);
        result.decode(self.net.classes, self.net.population);
        result
    }

    /// Functional run that also returns every layer's output spike train
    /// (spike-to-spike validation against the JAX reference).
    pub fn run_recording(&mut self, input: &SpikeTrain) -> (SimResult, Vec<SpikeTrain>) {
        let mut workload = SpikeTrainWorkload::new(input);
        let mut probe = TraceProbe::new(self.layers.len(), input.len());
        let mut result = self.run_engine(&mut workload, &mut probe);
        result.decode(self.net.classes, self.net.population);
        (result, probe.traces)
    }

    /// Activity-driven run: `activity[0]` is the input layer's spike count
    /// per step; `activity[l+1]` the l-th layer's output count per step.
    /// Only cycle/energy accounting is performed (no membrane arithmetic) —
    /// used for calibrated DVS workloads and large DSE sweeps.
    pub fn run_activity(&mut self, activity: &[Vec<usize>]) -> SimResult {
        let n_layers = self.layers.len();
        let mut workload = ActivityWorkload::new(activity, n_layers);
        self.run_engine(&mut workload, &mut NullProbe)
    }

    /// Batched serving run: the samples stream back-to-back through the
    /// layer pipeline, overlapping across sample boundaries exactly as the
    /// hardware would. Per-sample functional outputs are bit-identical to
    /// isolated `run` calls (layer state resets as each boundary passes
    /// through), while total latency is far below the sum of isolated
    /// runs. Returns the aggregate result plus one decoded prediction per
    /// sample.
    pub fn run_batched(&mut self, inputs: &[SpikeTrain]) -> (SimResult, Vec<Option<usize>>) {
        let (result, outcomes) = self.run_batched_timed(inputs);
        (result, outcomes.into_iter().map(|o| o.prediction).collect())
    }

    /// [`NetworkSim::run_batched`] that additionally reports, per sample,
    /// the pipelined cycle at which it fully left the final layer — the
    /// per-request completion times the serve runtime turns into queueing
    /// + execution latency. The last sample's completion equals the
    /// aggregate `total_cycles`.
    ///
    /// Uses [`BatchKernel::Auto`]: all-FC nets at serving batch sizes run
    /// on the bit-sliced kernel ([`crate::sim::batch_kernel`]), everything
    /// else on the per-sample engine. Results are byte-identical either
    /// way; use [`NetworkSim::run_batched_timed_with`] to force a kernel.
    pub fn run_batched_timed(&mut self, inputs: &[SpikeTrain]) -> (SimResult, Vec<BatchOutcome>) {
        self.run_batched_timed_with(inputs, BatchKernel::Auto)
    }

    /// [`NetworkSim::run_batched_timed`] with an explicit kernel choice.
    pub fn run_batched_timed_with(
        &mut self,
        inputs: &[SpikeTrain],
        kernel: BatchKernel,
    ) -> (SimResult, Vec<BatchOutcome>) {
        if selects_sliced(kernel, inputs.len(), &self.net) {
            return run_sliced(self, inputs);
        }
        let mut workload = BatchWorkload::new(inputs);
        let mut probe = BatchDecodeProbe::new(
            workload.t_per_sample(),
            self.net.classes,
            self.net.population,
        );
        let result = self.run_engine(&mut workload, &mut probe);
        let outcomes = probe
            .predictions
            .into_iter()
            .zip(probe.completions)
            .map(|(prediction, completion_cycles)| BatchOutcome {
                prediction,
                completion_cycles,
            })
            .collect();
        (result, outcomes)
    }

    /// Latency in seconds at the configured clock.
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_hz
    }
}

/// Random weights scaled like the Python init (Kaiming x2) so firing
/// activity is in a realistic regime.
pub fn random_weights(layer: &Layer, rng: &mut Rng) -> LayerWeights {
    match layer {
        Layer::Fc { n_pre, n } => {
            let scale = (2.0 / *n_pre as f64).sqrt() * 2.0;
            LayerWeights::Fc {
                w: (0..n_pre * n)
                    .map(|_| (rng.normal() * scale) as f32)
                    .collect(),
                b: vec![0.0; *n],
            }
        }
        Layer::Conv {
            in_ch,
            out_ch,
            kernel,
            ..
        } => {
            let fan_in = kernel * kernel * in_ch;
            let scale = (2.0 / fan_in as f64).sqrt() * 2.0;
            LayerWeights::Conv {
                w: (0..kernel * kernel * in_ch * out_ch)
                    .map(|_| (rng.normal() * scale) as f32)
                    .collect(),
                b: vec![0.0; *out_ch],
            }
        }
        Layer::Pool { .. } => LayerWeights::None,
    }
}

/// Encode an input spike train of `t` steps with Bernoulli(rate) bits —
/// the paper's rate coding, for simulator-only workloads.
pub fn random_spike_train(n_bits: usize, t: usize, rate: f64, rng: &mut Rng) -> SpikeTrain {
    (0..t)
        .map(|_| {
            let mut b = BitVec::zeros(n_bits);
            for i in 0..n_bits {
                if rng.bernoulli(rate) {
                    b.set(i);
                }
            }
            b
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::snn::fc_net;

    fn small_cfg(lhr: Vec<usize>) -> ExperimentConfig {
        let net = fc_net("tiny", "mnist", &[32, 16, 8], 4, 2, 0.9, 5);
        ExperimentConfig::new(net, HwConfig::with_lhr(lhr)).unwrap()
    }

    #[test]
    fn pipelined_no_slower_than_serial_no_faster_than_bottleneck() {
        let cfg = small_cfg(vec![1, 1]);
        let mut sim = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let mut rng = Rng::new(3);
        let input = random_spike_train(32, 5, 0.3, &mut rng);
        let r = sim.run(&input);
        assert!(r.total_cycles <= r.serial_cycles);
        let bottleneck = r.per_layer.iter().map(|l| l.busy_cycles).max().unwrap();
        assert!(r.total_cycles >= bottleneck);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = small_cfg(vec![2, 1]);
        let mut rng = Rng::new(3);
        let input = random_spike_train(32, 5, 0.3, &mut rng);
        let run = |seed| {
            let mut sim = NetworkSim::with_random_weights(&cfg, seed, CostModel::default());
            sim.run(&input).total_cycles
        };
        assert_eq!(run(7), run(7));
    }

    #[test]
    fn lhr_trades_latency_for_fewer_units() {
        let mut rng = Rng::new(3);
        let input = random_spike_train(32, 5, 0.4, &mut rng);
        let lat = |lhr: Vec<usize>| {
            let cfg = small_cfg(lhr);
            let mut sim = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
            sim.run(&input).total_cycles
        };
        // same weights/inputs: larger LHR can only increase latency
        assert!(lat(vec![4, 4]) >= lat(vec![1, 1]));
    }

    #[test]
    fn recording_traces_match_run() {
        let cfg = small_cfg(vec![1, 1]);
        let mut rng = Rng::new(9);
        let input = random_spike_train(32, 5, 0.3, &mut rng);
        let mut sim1 = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let r1 = sim1.run(&input);
        let mut sim2 = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let (r2, traces) = sim2.run_recording(&input);
        assert_eq!(r1.total_cycles, r2.total_cycles);
        assert_eq!(traces.len(), 2);
        assert_eq!(traces[1].len(), 5);
        // recorded final layer activity equals output counts
        let rec: u32 = traces[1].iter().map(|b| b.count_ones() as u32).sum();
        assert_eq!(rec, r2.output_counts.iter().sum::<u32>());
    }

    #[test]
    fn activity_mode_matches_functional_cycles() {
        // Drive the cost-only path with the spike counts recorded from a
        // functional run; latency must match exactly for FC networks.
        let cfg = small_cfg(vec![2, 2]);
        let mut rng = Rng::new(5);
        let input = random_spike_train(32, 6, 0.3, &mut rng);
        let mut fsim = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let (fr, traces) = fsim.run_recording(&input);
        let mut activity =
            vec![input.iter().map(|b| b.count_ones()).collect::<Vec<_>>()];
        for tr in &traces {
            activity.push(tr.iter().map(|b| b.count_ones()).collect());
        }
        let mut asim = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let ar = asim.run_activity(&activity);
        assert_eq!(fr.total_cycles, ar.total_cycles);
        assert_eq!(fr.serial_cycles, ar.serial_cycles);
    }

    #[test]
    fn repeated_runs_reuse_buffers_and_agree() {
        // back-to-back runs on one sim instance (with reset) must match a
        // fresh instance exactly — the ping-pong buffers carry no state
        // across runs.
        let cfg = small_cfg(vec![1, 2]);
        let mut rng = Rng::new(21);
        let input = random_spike_train(32, 5, 0.3, &mut rng);
        let mut reused = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let first = reused.run(&input);
        reused.reset();
        let second = reused.run(&input);
        assert_eq!(first.total_cycles, second.total_cycles);
        assert_eq!(first.output_counts, second.output_counts);
    }

    #[test]
    fn batched_predictions_match_isolated_runs() {
        let cfg = small_cfg(vec![1, 1]);
        let mut rng = Rng::new(13);
        let samples: Vec<SpikeTrain> = (0..4)
            .map(|_| random_spike_train(32, 5, 0.35, &mut rng))
            .collect();

        // isolated per-sample runs
        let mut isolated = Vec::new();
        for s in &samples {
            let mut sim = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
            isolated.push(sim.run(s));
        }

        let mut bsim = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let (batch, preds) = bsim.run_batched(&samples);

        assert_eq!(preds.len(), samples.len());
        for (p, r) in preds.iter().zip(&isolated) {
            assert_eq!(*p, r.predicted_class, "batched decode must match isolated");
        }
        // identical per-sample work => serial cycles add up exactly
        let serial_sum: u64 = isolated.iter().map(|r| r.serial_cycles).sum();
        assert_eq!(batch.serial_cycles, serial_sum);
        // pipelining across samples: cheaper than running them serially,
        // no cheaper than the last sample alone
        let total_sum: u64 = isolated.iter().map(|r| r.total_cycles).sum();
        assert!(batch.total_cycles <= total_sum);
        assert!(batch.total_cycles >= isolated.last().unwrap().total_cycles);
    }

    #[test]
    fn batched_timed_completions_are_monotone_and_end_at_total() {
        let cfg = small_cfg(vec![1, 2]);
        let mut rng = Rng::new(19);
        let samples: Vec<SpikeTrain> = (0..3)
            .map(|_| random_spike_train(32, 4, 0.3, &mut rng))
            .collect();
        let mut sim = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let (r, outcomes) = sim.run_batched_timed(&samples);
        assert_eq!(outcomes.len(), 3);
        for w in outcomes.windows(2) {
            assert!(w[0].completion_cycles < w[1].completion_cycles);
        }
        assert_eq!(outcomes.last().unwrap().completion_cycles, r.total_cycles);
        // predictions agree with the untimed wrapper
        let mut sim2 = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let (_, preds) = sim2.run_batched(&samples);
        let timed_preds: Vec<Option<usize>> =
            outcomes.iter().map(|o| o.prediction).collect();
        assert_eq!(timed_preds, preds);
    }

    #[test]
    fn batched_single_sample_equals_run() {
        let cfg = small_cfg(vec![2, 1]);
        let mut rng = Rng::new(17);
        let input = random_spike_train(32, 6, 0.3, &mut rng);
        let mut a = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let ra = a.run(&input);
        let mut b = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let (rb, preds) = b.run_batched(std::slice::from_ref(&input));
        assert_eq!(ra.total_cycles, rb.total_cycles);
        assert_eq!(ra.serial_cycles, rb.serial_cycles);
        assert_eq!(preds, vec![ra.predicted_class]);
    }
}
