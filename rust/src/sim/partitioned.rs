//! Pipelined multi-chip simulation: a [`PartitionedNetworkSim`] runs one
//! [`NetworkSim`] per chip of a [`PartitionPlan`] and threads the spike
//! stream through credit-based inter-chip links.
//!
//! ## Execution model
//!
//! Functional state is exact and link-independent: chips run in dataflow
//! order through the unified engine, each boundary spike train captured
//! by a probe and fed verbatim to the next chip. Links only reshape
//! *time*, never data, so timing is recovered by replaying the captured
//! per-layer, per-step costs through the analytic recurrence with the
//! link inserted at every chip boundary:
//!
//! ```text
//! accept[t]  = max(done[p][t], start_q[t-D])      credit (FIFO depth D)
//! arrival[t] = accept[t] + latency + ceil(spikes[t]/bandwidth)
//! ```
//!
//! where `p` is the boundary's producing layer and `start_q[t']` the
//! cycle its consumer began step `t'`. Holding the producer's output
//! register until the credit frees (`done[p][t] := accept[t]`) makes
//! back-pressure propagate upstream through the producer's own
//! next-step dependency — the same emit-to-consume credit window
//! [`crate::uarch::SpikeFifo`] models, which is also used here to replay
//! and *check* every boundary's credit protocol after the fact.
//!
//! ## Determinism contract
//!
//! With one chip (no boundary) — or any chip count under
//! [`LinkConfig::ideal`] links for total latency — the replay collapses
//! to `finish[l][t] = max(finish[l][t-1], finish[l-1][t]) + c_l(t)`,
//! i.e. exactly [`crate::sim::engine::Engine::run`]. The golden tests
//! pin byte-identity against [`NetworkSim`] on the Table-1 nets.

use crate::config::ExperimentConfig;
use crate::partition::{chip_config, LinkConfig, PartitionPlan};
use crate::sim::costs::CostModel;
use crate::sim::engine::{
    ActivityWorkload, BatchDecodeProbe, BatchWorkload, Probe, SpikeTrainWorkload, TeeProbe,
};
use crate::sim::layer::{LayerSim, LayerWeights};
use crate::sim::pipeline::{random_weights, BatchOutcome, NetworkSim};
use crate::sim::stats::{PhaseCycles, SimResult};
use crate::snn::{BitVec, SpikeTrain};
use crate::uarch::SpikeFifo;
use crate::util::rng::Rng;
use anyhow::Result;

/// Per-boundary stall/traffic accounting from the last timed replay.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LinkStats {
    /// Global index of the producing layer.
    pub boundary_layer: usize,
    /// Spikes that crossed the boundary.
    pub spikes: u64,
    /// Cycles producers spent holding finished steps for a credit.
    pub credit_wait: u64,
    /// Latency + serialization cycles added on the consumer side.
    pub serialization: u64,
    /// Peak buffered steps observed (validates against the FIFO depth).
    pub max_occupancy: usize,
}

/// A partitioned accelerator: one [`NetworkSim`] per chip, pipelined
/// through the plan's inter-chip links.
pub struct PartitionedNetworkSim {
    pub plan: PartitionPlan,
    pub chips: Vec<NetworkSim>,
    link: LinkConfig,
    classes: usize,
    population: usize,
    link_stats: Vec<LinkStats>,
}

/// Captures what the link replay needs from inside the engine loop:
/// every layer's per-step cost, plus (optionally) the last layer's
/// output train — the next chip's input.
struct ChipCapture {
    last_layer: usize,
    capture_boundary: bool,
    costs: Vec<Vec<u64>>,
    boundary: SpikeTrain,
}

impl ChipCapture {
    fn new(n_layers: usize, t_steps: usize, capture_boundary: bool) -> Self {
        ChipCapture {
            last_layer: n_layers - 1,
            capture_boundary,
            costs: vec![Vec::with_capacity(t_steps); n_layers],
            boundary: Vec::new(),
        }
    }
}

impl Probe for ChipCapture {
    fn on_layer_step(&mut self, l: usize, _t: usize, phases: &PhaseCycles, _layer: &LayerSim) {
        self.costs[l].push(phases.total());
    }
    fn on_layer_output(&mut self, l: usize, _t: usize, out: &BitVec) {
        if self.capture_boundary && l == self.last_layer {
            self.boundary.push(out.clone());
        }
    }
}

impl PartitionedNetworkSim {
    /// Build with the *full network's* random weight stream split across
    /// chips: one `Rng::new(seed)` draws weights in full-net parametric
    /// order (the exact sequence [`NetworkSim::with_random_weights`]
    /// draws), then each chip takes its contiguous slice — so a
    /// partitioned replica computes bit-identical spikes to the
    /// single-chip replica it stands in for.
    pub fn with_random_weights(
        cfg: &ExperimentConfig,
        plan: PartitionPlan,
        seed: u64,
        costs: CostModel,
    ) -> Result<Self> {
        let mut rng = Rng::new(seed);
        let all: Vec<LayerWeights> = cfg
            .net
            .parametric_layers()
            .iter()
            .map(|&i| random_weights(&cfg.net.layers[i], &mut rng))
            .collect();
        let mut w_iter = all.into_iter();
        let mut chips = Vec::with_capacity(plan.chips());
        for (c, &g) in plan.groups.iter().enumerate() {
            let ccfg = chip_config(cfg, g, c)?;
            let n_param = ccfg.net.parametric_layers().len();
            let w: Vec<LayerWeights> = w_iter.by_ref().take(n_param).collect();
            chips.push(NetworkSim::new(&ccfg, w, costs.clone()));
        }
        Ok(PartitionedNetworkSim {
            link: plan.links.first().map(|l| l.cfg).unwrap_or_else(LinkConfig::ideal),
            classes: cfg.net.classes,
            population: cfg.net.population,
            link_stats: Vec::new(),
            plan,
            chips,
        })
    }

    /// Cost-only chips for activity-driven runs (the DSE path).
    pub fn cost_only(cfg: &ExperimentConfig, plan: PartitionPlan, costs: CostModel) -> Result<Self> {
        let mut chips = Vec::with_capacity(plan.chips());
        for (c, &g) in plan.groups.iter().enumerate() {
            let ccfg = chip_config(cfg, g, c)?;
            chips.push(NetworkSim::cost_only(&ccfg, costs.clone()));
        }
        Ok(PartitionedNetworkSim {
            link: plan.links.first().map(|l| l.cfg).unwrap_or_else(LinkConfig::ideal),
            classes: cfg.net.classes,
            population: cfg.net.population,
            link_stats: Vec::new(),
            plan,
            chips,
        })
    }

    pub fn reset(&mut self) {
        for chip in &mut self.chips {
            chip.reset();
        }
    }

    /// Per-boundary link accounting from the most recent run.
    pub fn link_stats(&self) -> &[LinkStats] {
        &self.link_stats
    }

    /// Functional run over one input spike train.
    pub fn run(&mut self, input: &SpikeTrain) -> SimResult {
        let t_steps = input.len();
        let n_chips = self.chips.len();
        let mut costs: Vec<Vec<u64>> = Vec::new();
        let mut boundary_spikes: Vec<Vec<u64>> = Vec::new();
        let mut chip_results: Vec<SimResult> = Vec::new();
        let mut owned: SpikeTrain = Vec::new();
        for c in 0..n_chips {
            let cur: &SpikeTrain = if c == 0 { input } else { &owned };
            let n_layers = self.chips[c].layers.len();
            let mut probe = ChipCapture::new(n_layers, t_steps, c + 1 < n_chips);
            let mut workload = SpikeTrainWorkload::new(cur);
            let r = self.chips[c].run_engine(&mut workload, &mut probe);
            costs.append(&mut probe.costs);
            chip_results.push(r);
            if c + 1 < n_chips {
                boundary_spikes
                    .push(probe.boundary.iter().map(|b| b.count_ones() as u64).collect());
                owned = probe.boundary;
            }
        }
        let mut result = self.assemble(chip_results, &costs, &boundary_spikes, t_steps).0;
        result.decode(self.classes, self.population);
        result
    }

    /// Activity-driven (cost-only) run: `activity[0]` is the network
    /// input counts, `activity[l+1]` global layer `l`'s output counts —
    /// the same convention as [`NetworkSim::run_activity`]; boundary
    /// traffic is read straight from the producing layer's row.
    pub fn run_activity(&mut self, activity: &[Vec<usize>]) -> SimResult {
        let n_layers: usize = self.chips.iter().map(|c| c.layers.len()).sum();
        assert_eq!(
            activity.len(),
            n_layers + 1,
            "activity needs input + one entry per global layer"
        );
        let t_steps = activity[0].len();
        let groups = self.plan.groups.clone();
        let mut costs: Vec<Vec<u64>> = Vec::new();
        let mut chip_results: Vec<SimResult> = Vec::new();
        for (c, &(start, end)) in groups.iter().enumerate() {
            let slice = &activity[start..=end];
            let mut probe = ChipCapture::new(end - start, t_steps, false);
            let mut workload = ActivityWorkload::new(slice, end - start);
            let r = self.chips[c].run_engine(&mut workload, &mut probe);
            costs.append(&mut probe.costs);
            chip_results.push(r);
        }
        let boundary_spikes: Vec<Vec<u64>> = self
            .plan
            .groups
            .windows(2)
            .map(|w| activity[w[0].1].iter().map(|&s| s as u64).collect())
            .collect();
        self.assemble(chip_results, &costs, &boundary_spikes, t_steps).0
    }

    /// Batched serving run with per-sample completions, the partitioned
    /// analogue of [`NetworkSim::run_batched_timed`]. Samples stream
    /// back-to-back through every chip; the captured boundary train is
    /// re-chunked per sample so each downstream chip resets its membrane
    /// state at the same sample boundaries the single-chip engine does.
    pub fn run_batched_timed(&mut self, inputs: &[SpikeTrain]) -> (SimResult, Vec<BatchOutcome>) {
        assert!(!inputs.is_empty(), "batch needs at least one sample");
        let tps = inputs[0].len();
        let n_chips = self.chips.len();
        let t_steps = inputs.len() * tps;
        let mut costs: Vec<Vec<u64>> = Vec::new();
        let mut boundary_spikes: Vec<Vec<u64>> = Vec::new();
        let mut chip_results: Vec<SimResult> = Vec::new();
        let mut owned: Vec<SpikeTrain> = Vec::new();
        let mut decode = BatchDecodeProbe::new(tps, self.classes, self.population);
        for c in 0..n_chips {
            let cur: &[SpikeTrain] = if c == 0 { inputs } else { &owned };
            let n_layers = self.chips[c].layers.len();
            let mut probe = ChipCapture::new(n_layers, t_steps, c + 1 < n_chips);
            let mut workload = BatchWorkload::new(cur);
            let r = if c + 1 == n_chips {
                let mut tee = TeeProbe { a: &mut probe, b: &mut decode };
                self.chips[c].run_engine(&mut workload, &mut tee)
            } else {
                self.chips[c].run_engine(&mut workload, &mut probe)
            };
            costs.append(&mut probe.costs);
            chip_results.push(r);
            if c + 1 < n_chips {
                boundary_spikes
                    .push(probe.boundary.iter().map(|b| b.count_ones() as u64).collect());
                owned = probe
                    .boundary
                    .chunks(tps)
                    .map(|chunk| chunk.to_vec())
                    .collect();
            }
        }
        let (result, finish_last) = self.assemble(chip_results, &costs, &boundary_spikes, t_steps);
        let outcomes = decode
            .predictions
            .into_iter()
            .enumerate()
            .map(|(s, prediction)| BatchOutcome {
                prediction,
                completion_cycles: finish_last[(s + 1) * tps - 1],
            })
            .collect();
        (result, outcomes)
    }

    /// Merge per-chip engine results and replay the captured costs with
    /// links inserted at every boundary. Returns the assembled result
    /// plus the final layer's per-step finish times (batched completion
    /// accounting reads per-sample boundaries out of it).
    fn assemble(
        &mut self,
        chip_results: Vec<SimResult>,
        costs: &[Vec<u64>],
        boundary_spikes: &[Vec<u64>],
        t_steps: usize,
    ) -> (SimResult, Vec<u64>) {
        let (total_cycles, finish_last, link_stats) =
            replay_links(costs, &self.plan.groups, boundary_spikes, self.link);
        self.link_stats = link_stats;
        let serial_cycles = chip_results.iter().map(|r| r.serial_cycles).sum();
        let mut per_layer = Vec::with_capacity(costs.len());
        for (&(start, _), r) in self.plan.groups.iter().zip(&chip_results) {
            for (local, mut stats) in r.per_layer.iter().cloned().enumerate() {
                let global = start + local;
                let kind = self.plan_layer_kind(global);
                stats.name = format!("{kind}{global}");
                per_layer.push(stats);
            }
        }
        let last = chip_results.last().expect("at least one chip");
        let result = SimResult {
            total_cycles,
            serial_cycles,
            per_layer,
            t_steps,
            output_counts: last.output_counts.clone(),
            predicted_class: None,
        };
        (result, finish_last)
    }

    fn plan_layer_kind(&self, global: usize) -> &'static str {
        // chips carry NetDef slices, so recover the kind from the chip
        // that owns the global layer
        for (c, &(start, end)) in self.plan.groups.iter().enumerate() {
            if global >= start && global < end {
                return self.chips[c].net.layers[global - start].kind_str();
            }
        }
        unreachable!("global layer {global} outside every group")
    }
}

/// Replay per-layer, per-step costs through the pipelined recurrence
/// with a credit-based link at every chip boundary. Pure function of its
/// inputs; with ideal links it IS the analytic recurrence.
///
/// Returns `(total_cycles, final-layer finish per step, per-link stats)`.
fn replay_links(
    costs: &[Vec<u64>],
    groups: &[(usize, usize)],
    boundary_spikes: &[Vec<u64>],
    link: LinkConfig,
) -> (u64, Vec<u64>, Vec<LinkStats>) {
    let n_layers = costs.len();
    let t_steps = costs.first().map(|c| c.len()).unwrap_or(0);
    let n_bounds = groups.len() - 1;
    debug_assert_eq!(boundary_spikes.len(), n_bounds);
    // boundary b: producer = groups[b].1 - 1, consumer = producer + 1
    let mut producer_of = vec![usize::MAX; n_layers];
    for (b, g) in groups[..n_bounds].iter().enumerate() {
        producer_of[g.1 - 1] = b;
    }
    let mut finish = vec![vec![0u64; t_steps]; n_layers];
    let mut accepts = vec![vec![0u64; t_steps]; n_bounds];
    let mut starts = vec![vec![0u64; t_steps]; n_bounds];
    let mut stats: Vec<LinkStats> = groups[..n_bounds]
        .iter()
        .map(|g| LinkStats { boundary_layer: g.1 - 1, ..LinkStats::default() })
        .collect();

    for t in 0..t_steps {
        let mut upstream = 0u64; // when layer g's step-t input is available
        let mut pending_boundary: Option<usize> = None;
        for g in 0..n_layers {
            let own_prev = if t == 0 { 0 } else { finish[g][t - 1] };
            let start = own_prev.max(upstream);
            if let Some(b) = pending_boundary.take() {
                starts[b][t] = start; // the link consumer began step t
            }
            finish[g][t] = start + costs[g][t];
            let b = producer_of[g];
            if b == usize::MAX {
                upstream = finish[g][t];
            } else {
                // hold the finished step until a FIFO credit is free:
                // depth D means the consumer must have *started* step
                // t-D before step t can be emitted
                let raw = finish[g][t];
                let mut accept = raw;
                let d = link.fifo_depth;
                if d > 0 && t >= d {
                    accept = accept.max(starts[b][t - d]);
                }
                stats[b].credit_wait += accept - raw;
                finish[g][t] = accept; // back-pressure: next step waits
                accepts[b][t] = accept;
                let xfer = if link.bandwidth == 0 {
                    0
                } else {
                    boundary_spikes[b][t].div_ceil(link.bandwidth)
                };
                stats[b].spikes += boundary_spikes[b][t];
                stats[b].serialization += link.latency + xfer;
                upstream = accept + link.latency + xfer;
                pending_boundary = Some(b);
            }
        }
    }

    // Replay every boundary through a real SpikeFifo in simulated-time
    // order: a slot is held from producer emit (accept) to consumer
    // start. `push` panics if the accept rule ever over-fills the FIFO,
    // so this doubles as a credit-protocol check on the recurrence.
    for (b, stat) in stats.iter_mut().enumerate() {
        // merge the in-order push (emit) and pop (consumer-start) streams
        // by simulated time; at equal timestamps an *earlier* step's pop
        // frees its credit before the push uses it, while a step can
        // never pop before its own push
        let mut fifo = SpikeFifo::new(link.fifo_depth);
        let (mut pi, mut qi) = (0usize, 0usize);
        while pi < t_steps || qi < t_steps {
            let do_pop = qi < t_steps
                && (pi >= t_steps
                    || starts[b][qi] < accepts[b][pi]
                    || (starts[b][qi] == accepts[b][pi] && qi < pi));
            if do_pop {
                fifo.pop();
                qi += 1;
            } else {
                fifo.push();
                pi += 1;
            }
        }
        stat.max_occupancy = fifo.max_occupancy();
    }

    let finish_last = finish.last().cloned().unwrap_or_default();
    let total = finish_last.last().copied().unwrap_or(0);
    (total, finish_last, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::partition::{partition, PartitionOptions};
    use crate::sim::pipeline::random_spike_train;
    use crate::snn::fc_net;

    fn tiny_cfg() -> ExperimentConfig {
        let net = fc_net("tinyp", "mnist", &[32, 24, 16, 8], 4, 2, 0.9, 6);
        ExperimentConfig::new(net, HwConfig::with_lhr(vec![2, 1, 2])).unwrap()
    }

    fn build(cfg: &ExperimentConfig, chips: usize, link: LinkConfig) -> PartitionedNetworkSim {
        let opts = PartitionOptions { chips, link, ..PartitionOptions::single_chip() };
        let plan = partition(cfg, &opts).unwrap();
        PartitionedNetworkSim::with_random_weights(cfg, plan, 7, CostModel::default()).unwrap()
    }

    #[test]
    fn single_chip_ideal_matches_network_sim_exactly() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(11);
        let input = random_spike_train(32, 6, 0.3, &mut rng);
        let mut single = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let expect = single.run(&input);
        let mut part = build(&cfg, 1, LinkConfig::ideal());
        let got = part.run(&input);
        assert_eq!(got.total_cycles, expect.total_cycles);
        assert_eq!(got.serial_cycles, expect.serial_cycles);
        assert_eq!(got.output_counts, expect.output_counts);
        assert_eq!(got.predicted_class, expect.predicted_class);
        assert!(part.link_stats().is_empty());
    }

    #[test]
    fn multi_chip_ideal_links_keep_the_analytic_latency() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(12);
        let input = random_spike_train(32, 6, 0.35, &mut rng);
        let mut single = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let expect = single.run(&input);
        for chips in [2usize, 3] {
            let mut part = build(&cfg, chips, LinkConfig::ideal());
            let got = part.run(&input);
            assert_eq!(got.total_cycles, expect.total_cycles, "{chips} chips");
            assert_eq!(got.serial_cycles, expect.serial_cycles);
            assert_eq!(got.output_counts, expect.output_counts);
            assert_eq!(got.predicted_class, expect.predicted_class);
            // ideal links stall nothing
            for ls in part.link_stats() {
                assert_eq!(ls.credit_wait, 0);
                assert_eq!(ls.serialization, 0);
            }
        }
    }

    #[test]
    fn finite_links_never_change_function_and_never_speed_up() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(13);
        let input = random_spike_train(32, 6, 0.35, &mut rng);
        let mut ideal = build(&cfg, 2, LinkConfig::ideal());
        let base = ideal.run(&input);
        let mut slow = build(&cfg, 2, LinkConfig { latency: 16, bandwidth: 2, fifo_depth: 1 });
        let got = slow.run(&input);
        assert_eq!(got.output_counts, base.output_counts, "links reshape time, not data");
        assert_eq!(got.predicted_class, base.predicted_class);
        assert!(got.total_cycles > base.total_cycles);
        let ls = &slow.link_stats()[0];
        assert!(ls.serialization > 0);
        assert!(ls.spikes > 0);
        assert!(ls.max_occupancy <= 1, "depth-1 FIFO can hold at most one step");
    }

    #[test]
    fn link_latency_is_monotone_in_every_knob() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(14);
        let input = random_spike_train(32, 6, 0.4, &mut rng);
        let cycles = |link: LinkConfig| {
            let mut sim = build(&cfg, 3, link);
            sim.run(&input).total_cycles
        };
        let base = cycles(LinkConfig { latency: 4, bandwidth: 8, fifo_depth: 8 });
        assert!(cycles(LinkConfig { latency: 32, bandwidth: 8, fifo_depth: 8 }) >= base);
        assert!(cycles(LinkConfig { latency: 4, bandwidth: 1, fifo_depth: 8 }) >= base);
        assert!(cycles(LinkConfig { latency: 4, bandwidth: 8, fifo_depth: 1 }) >= base);
    }

    #[test]
    fn activity_replay_matches_functional_cycles() {
        // the same identity NetworkSim pins for the single-chip engine:
        // cost-only replay of recorded activity must reproduce the
        // functional run's latency, links included
        let cfg = tiny_cfg();
        let mut rng = Rng::new(15);
        let input = random_spike_train(32, 6, 0.3, &mut rng);
        let link = LinkConfig { latency: 8, bandwidth: 4, fifo_depth: 2 };
        let mut fsim = build(&cfg, 2, link);
        let fr = fsim.run(&input);
        // record global activity from a single-chip functional run
        let mut single = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let (_, traces) = single.run_recording(&input);
        let mut activity = vec![input.iter().map(|b| b.count_ones()).collect::<Vec<_>>()];
        for tr in &traces {
            activity.push(tr.iter().map(|b| b.count_ones()).collect());
        }
        let plan = partition(
            &cfg,
            &PartitionOptions { chips: 2, link, ..PartitionOptions::single_chip() },
        )
        .unwrap();
        let mut asim =
            PartitionedNetworkSim::cost_only(&cfg, plan, CostModel::default()).unwrap();
        let ar = asim.run_activity(&activity);
        assert_eq!(fr.total_cycles, ar.total_cycles);
        assert_eq!(fr.serial_cycles, ar.serial_cycles);
        assert_eq!(fsim.link_stats(), asim.link_stats());
    }

    #[test]
    fn batched_single_chip_matches_network_sim() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(16);
        let samples: Vec<SpikeTrain> =
            (0..3).map(|_| random_spike_train(32, 6, 0.3, &mut rng)).collect();
        let mut single = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let (er, eo) = single.run_batched_timed(&samples);
        let mut part = build(&cfg, 1, LinkConfig::ideal());
        let (gr, go) = part.run_batched_timed(&samples);
        assert_eq!(gr.total_cycles, er.total_cycles);
        assert_eq!(gr.serial_cycles, er.serial_cycles);
        assert_eq!(go, eo);
    }

    #[test]
    fn batched_multi_chip_preserves_predictions_and_orders_completions() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(17);
        let samples: Vec<SpikeTrain> =
            (0..4).map(|_| random_spike_train(32, 6, 0.35, &mut rng)).collect();
        let mut single = NetworkSim::with_random_weights(&cfg, 7, CostModel::default());
        let (_, eo) = single.run_batched_timed(&samples);
        let mut part = build(&cfg, 2, LinkConfig { latency: 8, bandwidth: 4, fifo_depth: 2 });
        let (gr, go) = part.run_batched_timed(&samples);
        let epreds: Vec<_> = eo.iter().map(|o| o.prediction).collect();
        let gpreds: Vec<_> = go.iter().map(|o| o.prediction).collect();
        assert_eq!(gpreds, epreds, "links must not change functional outputs");
        for w in go.windows(2) {
            assert!(w[0].completion_cycles < w[1].completion_cycles);
        }
        assert_eq!(go.last().unwrap().completion_cycles, gr.total_cycles);
        // finite links delay every completion relative to ideal
        let mut ideal = build(&cfg, 2, LinkConfig::ideal());
        let (_, io) = ideal.run_batched_timed(&samples);
        for (g, i) in go.iter().zip(&io) {
            assert!(g.completion_cycles >= i.completion_cycles);
        }
    }

    #[test]
    fn per_layer_stats_use_global_names() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(18);
        let input = random_spike_train(32, 6, 0.3, &mut rng);
        let mut part = build(&cfg, 3, LinkConfig::ideal());
        let r = part.run(&input);
        let names: Vec<&str> = r.per_layer.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["fc0", "fc1", "fc2"]);
    }
}
