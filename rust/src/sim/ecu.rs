//! Event Control Unit state machine (paper §V-B, Fig. 4).
//!
//! The ECU sequences each time step through IDLE -> COMPRESS -> ACCUMULATE
//! -> ACTIVATE -> EMIT and synchronizes with the pre-/post-synaptic layers
//! (receive handshake on entry, notify handshake on EMIT). `LayerSim`
//! charges the aggregate `phase_overhead`; this module models the FSM at
//! one-transition-per-cycle granularity so the overhead constant is
//! *derived*, and provides the per-step trace used at verbosity >= 3.

use crate::sim::stats::PhaseCycles;

/// ECU states, in canonical order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EcuState {
    Idle,
    Compress,
    Accumulate,
    Activate,
    Emit,
}

/// One FSM transition record (for tracing / validation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transition {
    pub from: EcuState,
    pub to: EcuState,
    /// Cycle (within the step) at which the transition fires.
    pub at_cycle: u64,
}

/// Cycle-level model of one ECU step.
#[derive(Debug, Clone)]
pub struct EcuFsm {
    pub state: EcuState,
    /// Completed transitions this step.
    pub trace: Vec<Transition>,
    cycle: u64,
}

impl Default for EcuFsm {
    fn default() -> Self {
        Self::new()
    }
}

impl EcuFsm {
    pub fn new() -> Self {
        EcuFsm {
            state: EcuState::Idle,
            trace: Vec::new(),
            cycle: 0,
        }
    }

    /// Transitions per time step: IDLE->COMPRESS, COMPRESS->ACCUM,
    /// ACCUM->ACTIVATE, ACTIVATE->EMIT (1 cycle each, the handshake /
    /// control-register update). EMIT->IDLE overlaps the next receive, so
    /// the steady-state overhead is 4 — this *derives* the
    /// `CostModel::phase_overhead` default.
    pub const TRANSITIONS_PER_STEP: u64 = 4;

    fn goto(&mut self, to: EcuState) {
        self.cycle += 1; // each transition costs one control cycle
        self.trace.push(Transition {
            from: self.state,
            to,
            at_cycle: self.cycle,
        });
        self.state = to;
    }

    /// Run one full step given the phase *work* durations; returns total
    /// cycles including transition overhead.
    pub fn run_step(&mut self, compress: u64, accumulate: u64, activate: u64) -> u64 {
        assert_eq!(self.state, EcuState::Idle, "step starting mid-flight");
        self.trace.clear();
        self.cycle = 0;
        self.goto(EcuState::Compress);
        self.cycle += compress;
        self.goto(EcuState::Accumulate);
        self.cycle += accumulate;
        self.goto(EcuState::Activate);
        self.cycle += activate;
        self.goto(EcuState::Emit);
        // EMIT->IDLE overlaps the next spike-train receive (layer-wise
        // pipelining, §V-B): not charged.
        self.state = EcuState::Idle;
        self.cycle
    }

    /// The overhead this FSM adds on top of the three work phases.
    pub fn overhead(&self) -> u64 {
        Self::TRANSITIONS_PER_STEP
    }

    /// Check a `PhaseCycles` record is consistent with this FSM's model.
    pub fn consistent_with(&self, p: &PhaseCycles) -> bool {
        p.overhead == Self::TRANSITIONS_PER_STEP
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::costs::CostModel;

    #[test]
    fn canonical_transition_sequence() {
        let mut fsm = EcuFsm::new();
        let total = fsm.run_step(10, 20, 5);
        assert_eq!(total, 10 + 20 + 5 + EcuFsm::TRANSITIONS_PER_STEP);
        let seq: Vec<(EcuState, EcuState)> =
            fsm.trace.iter().map(|t| (t.from, t.to)).collect();
        assert_eq!(
            seq,
            vec![
                (EcuState::Idle, EcuState::Compress),
                (EcuState::Compress, EcuState::Accumulate),
                (EcuState::Accumulate, EcuState::Activate),
                (EcuState::Activate, EcuState::Emit),
            ]
        );
        assert_eq!(fsm.state, EcuState::Idle); // ready for the next step
    }

    #[test]
    fn transition_timestamps_monotone() {
        let mut fsm = EcuFsm::new();
        fsm.run_step(3, 7, 2);
        let at: Vec<u64> = fsm.trace.iter().map(|t| t.at_cycle).collect();
        assert!(at.windows(2).all(|w| w[0] < w[1]), "{at:?}");
        assert_eq!(at[0], 1);
        assert_eq!(*at.last().unwrap(), 3 + 7 + 2 + 4);
    }

    #[test]
    fn derives_cost_model_overhead() {
        // The CostModel's phase_overhead must equal the FSM's transition
        // count — the constant is derived, not tuned.
        assert_eq!(CostModel::default().phase_overhead, EcuFsm::TRANSITIONS_PER_STEP);
    }

    #[test]
    fn zero_work_step_costs_only_overhead() {
        let mut fsm = EcuFsm::new();
        assert_eq!(fsm.run_step(0, 0, 0), EcuFsm::TRANSITIONS_PER_STEP);
    }

    #[test]
    fn repeated_steps_reset_cleanly() {
        let mut fsm = EcuFsm::new();
        let a = fsm.run_step(5, 5, 5);
        let b = fsm.run_step(5, 5, 5);
        assert_eq!(a, b);
        assert_eq!(fsm.trace.len(), 4);
    }
}
