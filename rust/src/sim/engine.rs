//! The unified simulation engine: ONE pipelined scheduler loop shared by
//! every execution mode of the simulator.
//!
//! The paper's layer-wise pipelining (§V-B) is a single recurrence over
//! per-layer, per-step costs:
//!
//! ```text
//! finish[l][t] = max(finish[l][t-1], finish[l-1][t]) + c_l(t)
//! ```
//!
//! Historically `NetworkSim::run`, `run_recording` and `run_activity` each
//! re-implemented that loop with divergent bookkeeping and per-step
//! `BitVec` clones. They are now thin wrappers over [`Engine::run`],
//! parameterized on two small traits:
//!
//! * [`Workload`] — *what* drives each layer step: a functional spike
//!   train ([`SpikeTrainWorkload`]), calibrated activity counts
//!   ([`ActivityWorkload`]), or a batched multi-input stream for
//!   serving-style throughput ([`BatchWorkload`], samples flow
//!   back-to-back through the layer pipeline).
//! * [`Probe`] — *what* is observed: nothing ([`NullProbe`]), per-layer
//!   trace capture ([`TraceProbe`]), or per-sample output decoding
//!   ([`BatchDecodeProbe`]).
//!
//! The engine owns a pair of ping-pong spike buffers reused across every
//! step and layer (via [`BitVec::copy_from`] / `fill_from_bools`), so the
//! functional hot path performs **zero steady-state allocations per step**.

use crate::sim::layer::LayerSim;
use crate::sim::stats::{decode_counts, PhaseCycles, SimResult};
use crate::snn::{BitVec, SpikeTrain};

/// One update of the pipelined finish-time recurrence. This helper is the
/// single place in the codebase where the recurrence lives — the engine,
/// the dynamic-allocation ablation and the sparsity-oblivious baseline all
/// call it.
#[inline]
pub fn advance_finish(finish: &mut u64, prev_finish: u64, cost: u64) -> u64 {
    *finish = (*finish).max(prev_finish) + cost;
    *finish
}

/// Drives the per-layer work of one execution mode.
pub trait Workload {
    /// Total time steps to schedule.
    fn t_steps(&self) -> usize;

    /// Whether this workload propagates real spike trains (functional
    /// modes). Cost-only workloads return `false` and the engine skips
    /// buffer plumbing and output counting.
    fn is_functional(&self) -> bool {
        true
    }

    /// Write the step-`t` network input into `input` (no-op for cost-only
    /// workloads).
    fn begin_step(&mut self, t: usize, input: &mut BitVec);

    /// Advance layer `l` at step `t`, returning its cycle breakdown.
    /// Functional workloads consume `input` and fill `output`; cost-only
    /// workloads ignore both buffers.
    fn step_layer(
        &mut self,
        layer: &mut LayerSim,
        l: usize,
        t: usize,
        input: &BitVec,
        output: &mut BitVec,
    ) -> PhaseCycles;
}

/// Observer hooks over a run. All methods default to no-ops. Spike-train
/// hooks (`on_layer_output` / `on_network_output` / `on_step_finish`)
/// fire only for functional workloads; [`Probe::on_layer_step`] fires
/// for every workload, cost-only included.
pub trait Probe {
    /// Layer `l` finished its step-`t` work at a cost of `phases` —
    /// called for *every* workload right after
    /// [`Workload::step_layer`] returns, with the layer's post-step
    /// state readable. The uarch trace recorder hooks here, so per-step
    /// costs are observed from the engine's own loop rather than a
    /// re-implementation of it.
    fn on_layer_step(&mut self, _l: usize, _t: usize, _phases: &PhaseCycles, _layer: &LayerSim) {}
    /// Layer `l` produced its step-`t` output spike train.
    fn on_layer_output(&mut self, _l: usize, _t: usize, _out: &BitVec) {}
    /// The network's final layer produced its step-`t` output.
    fn on_network_output(&mut self, _t: usize, _out: &BitVec) {}
    /// The pipelined finish time (cycles) of the final layer after step
    /// `t` — called right after [`Probe::on_network_output`]. Batched
    /// serving uses this to read per-sample completion times out of the
    /// scheduler without re-deriving the recurrence.
    fn on_step_finish(&mut self, _t: usize, _finish_cycles: u64) {}
}

/// Probe that observes nothing (plain latency/stats runs).
pub struct NullProbe;

impl Probe for NullProbe {}

/// Forwards every hook to two probes in order — chained observers, e.g.
/// the partitioned simulator's per-step cost capture running alongside
/// the per-sample [`BatchDecodeProbe`] on the final chip.
pub struct TeeProbe<'a, A: Probe, B: Probe> {
    pub a: &'a mut A,
    pub b: &'a mut B,
}

impl<A: Probe, B: Probe> Probe for TeeProbe<'_, A, B> {
    fn on_layer_step(&mut self, l: usize, t: usize, phases: &PhaseCycles, layer: &LayerSim) {
        self.a.on_layer_step(l, t, phases, layer);
        self.b.on_layer_step(l, t, phases, layer);
    }
    fn on_layer_output(&mut self, l: usize, t: usize, out: &BitVec) {
        self.a.on_layer_output(l, t, out);
        self.b.on_layer_output(l, t, out);
    }
    fn on_network_output(&mut self, t: usize, out: &BitVec) {
        self.a.on_network_output(t, out);
        self.b.on_network_output(t, out);
    }
    fn on_step_finish(&mut self, t: usize, finish_cycles: u64) {
        self.a.on_step_finish(t, finish_cycles);
        self.b.on_step_finish(t, finish_cycles);
    }
}

/// Captures every layer's full output spike train (spike-to-spike
/// validation against the JAX reference).
pub struct TraceProbe {
    pub traces: Vec<SpikeTrain>,
}

impl TraceProbe {
    pub fn new(n_layers: usize, t_steps: usize) -> Self {
        TraceProbe {
            traces: vec![Vec::with_capacity(t_steps); n_layers],
        }
    }
}

impl Probe for TraceProbe {
    fn on_layer_output(&mut self, l: usize, _t: usize, out: &BitVec) {
        self.traces[l].push(out.clone());
    }
}

/// Decodes the population-coded output per sample of a batched run.
pub struct BatchDecodeProbe {
    t_per_sample: usize,
    classes: usize,
    population: usize,
    counts: Vec<u32>,
    /// One prediction per completed sample, in arrival order.
    pub predictions: Vec<Option<usize>>,
    /// Pipelined finish time (cycles) of each sample's last step — when
    /// sample `i` fully left the final layer. Serving latency accounting
    /// reads per-sample completions from here.
    pub completions: Vec<u64>,
}

impl BatchDecodeProbe {
    pub fn new(t_per_sample: usize, classes: usize, population: usize) -> Self {
        assert!(t_per_sample > 0, "samples must span at least one step");
        BatchDecodeProbe {
            t_per_sample,
            classes,
            population,
            counts: Vec::new(),
            predictions: Vec::new(),
            completions: Vec::new(),
        }
    }
}

impl Probe for BatchDecodeProbe {
    fn on_network_output(&mut self, t: usize, out: &BitVec) {
        if self.counts.len() != out.len() {
            self.counts = vec![0; out.len()];
        }
        let counts = &mut self.counts;
        out.for_each_one(|i| counts[i] += 1);
        if (t + 1) % self.t_per_sample == 0 {
            self.predictions
                .push(decode_counts(&self.counts, self.classes, self.population));
            self.counts.iter_mut().for_each(|c| *c = 0);
        }
    }

    fn on_step_finish(&mut self, t: usize, finish_cycles: u64) {
        if (t + 1) % self.t_per_sample == 0 {
            self.completions.push(finish_cycles);
        }
    }
}

/// Functional workload over one input spike train.
pub struct SpikeTrainWorkload<'a> {
    input: &'a SpikeTrain,
}

impl<'a> SpikeTrainWorkload<'a> {
    pub fn new(input: &'a SpikeTrain) -> Self {
        SpikeTrainWorkload { input }
    }
}

impl Workload for SpikeTrainWorkload<'_> {
    fn t_steps(&self) -> usize {
        self.input.len()
    }
    fn begin_step(&mut self, t: usize, input: &mut BitVec) {
        input.copy_from(&self.input[t]);
    }
    fn step_layer(
        &mut self,
        layer: &mut LayerSim,
        _l: usize,
        _t: usize,
        input: &BitVec,
        output: &mut BitVec,
    ) -> PhaseCycles {
        layer.step_into(input, output)
    }
}

/// Cost-only workload driven by calibrated per-layer spike counts
/// (`activity[0]` = input stage; `activity[l+1]` = layer `l`'s output).
pub struct ActivityWorkload<'a> {
    activity: &'a [Vec<usize>],
}

impl<'a> ActivityWorkload<'a> {
    pub fn new(activity: &'a [Vec<usize>], n_layers: usize) -> Self {
        assert_eq!(
            activity.len(),
            n_layers + 1,
            "activity needs input + one entry per layer"
        );
        ActivityWorkload { activity }
    }
}

impl Workload for ActivityWorkload<'_> {
    fn t_steps(&self) -> usize {
        self.activity[0].len()
    }
    fn is_functional(&self) -> bool {
        false
    }
    fn begin_step(&mut self, _t: usize, _input: &mut BitVec) {}
    fn step_layer(
        &mut self,
        layer: &mut LayerSim,
        l: usize,
        t: usize,
        _input: &BitVec,
        _output: &mut BitVec,
    ) -> PhaseCycles {
        layer.step_cost_only(self.activity[l][t], self.activity[l + 1][t])
    }
}

/// Batched multi-input workload: samples stream back-to-back through the
/// layer pipeline (serving-style throughput). Sample `i+1`'s first step
/// enters layer 0 as soon as sample `i`'s last step has left it; each
/// layer's functional state resets when a sample boundary passes through
/// it, so per-sample outputs are bit-identical to isolated runs while
/// latency overlaps across samples.
pub struct BatchWorkload<'a> {
    inputs: &'a [SpikeTrain],
    t_per_sample: usize,
}

impl<'a> BatchWorkload<'a> {
    pub fn new(inputs: &'a [SpikeTrain]) -> Self {
        assert!(!inputs.is_empty(), "batch needs at least one sample");
        let t_per_sample = inputs[0].len();
        assert!(t_per_sample > 0, "samples must span at least one step");
        assert!(
            inputs.iter().all(|s| s.len() == t_per_sample),
            "all batch samples must share the same spike-train length"
        );
        BatchWorkload {
            inputs,
            t_per_sample,
        }
    }

    pub fn t_per_sample(&self) -> usize {
        self.t_per_sample
    }
}

impl Workload for BatchWorkload<'_> {
    fn t_steps(&self) -> usize {
        self.inputs.len() * self.t_per_sample
    }
    fn begin_step(&mut self, t: usize, input: &mut BitVec) {
        input.copy_from(&self.inputs[t / self.t_per_sample][t % self.t_per_sample]);
    }
    fn step_layer(
        &mut self,
        layer: &mut LayerSim,
        _l: usize,
        t: usize,
        input: &BitVec,
        output: &mut BitVec,
    ) -> PhaseCycles {
        if t % self.t_per_sample == 0 {
            // the sample boundary reaches this layer now: fresh membrane
            layer.reset_state();
        }
        layer.step_into(input, output)
    }
}

/// The pipelined scheduler. Owns the finish-time vector and the ping-pong
/// spike buffers so repeated runs on one [`crate::sim::NetworkSim`] reuse
/// all allocations.
pub struct Engine {
    finish: Vec<u64>,
    cur: BitVec,
    next: BitVec,
}

impl Default for Engine {
    fn default() -> Self {
        Engine::new()
    }
}

impl Engine {
    pub fn new() -> Self {
        Engine {
            finish: Vec::new(),
            cur: BitVec::zeros(0),
            next: BitVec::zeros(0),
        }
    }

    /// Run `workload` over `layers`, reporting outputs to `probe`.
    /// `out_bits` sizes the output-count accumulator (the final layer's
    /// output width). The returned [`SimResult`] is not yet decoded —
    /// callers that want a predicted class call `SimResult::decode`.
    pub fn run<W: Workload, P: Probe>(
        &mut self,
        layers: &mut [LayerSim],
        out_bits: usize,
        workload: &mut W,
        probe: &mut P,
    ) -> SimResult {
        let t_steps = workload.t_steps();
        let n_layers = layers.len();
        let functional = workload.is_functional();
        self.finish.clear();
        self.finish.resize(n_layers, 0);
        let mut serial = 0u64;
        let mut output_counts: Vec<u32> = if functional {
            vec![0; out_bits]
        } else {
            Vec::new()
        };

        for t in 0..t_steps {
            workload.begin_step(t, &mut self.cur);
            let mut prev_finish = 0u64;
            for (l, layer) in layers.iter_mut().enumerate() {
                let phases = workload.step_layer(layer, l, t, &self.cur, &mut self.next);
                probe.on_layer_step(l, t, &phases, layer);
                serial += phases.total();
                prev_finish = advance_finish(&mut self.finish[l], prev_finish, phases.total());
                if functional {
                    probe.on_layer_output(l, t, &self.next);
                    std::mem::swap(&mut self.cur, &mut self.next);
                }
            }
            if functional {
                self.cur.for_each_one(|idx| output_counts[idx] += 1);
                probe.on_network_output(t, &self.cur);
                if let Some(&f) = self.finish.last() {
                    probe.on_step_finish(t, f);
                }
            }
        }

        SimResult {
            total_cycles: self.finish.last().copied().unwrap_or(0),
            serial_cycles: serial,
            per_layer: layers.iter().map(|l| l.stats.clone()).collect(),
            t_steps,
            output_counts,
            predicted_class: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_finish_is_the_recurrence() {
        // layer stalled on its own previous step
        let mut f = 10u64;
        assert_eq!(advance_finish(&mut f, 3, 5), 15);
        // layer stalled on its producer
        let mut f = 3u64;
        assert_eq!(advance_finish(&mut f, 10, 5), 15);
    }

    #[test]
    fn on_layer_step_fires_for_cost_only_workloads() {
        // the spike-train hooks stay silent for cost-only runs, but the
        // per-layer cost hook must fire for every (layer, step) — the
        // uarch trace recorder depends on it
        struct CostCounter {
            calls: usize,
            total: u64,
        }
        impl Probe for CostCounter {
            fn on_layer_step(
                &mut self,
                _l: usize,
                _t: usize,
                phases: &PhaseCycles,
                _layer: &LayerSim,
            ) {
                self.calls += 1;
                self.total += phases.total();
            }
        }
        use crate::config::{ExperimentConfig, HwConfig};
        use crate::sim::costs::CostModel;
        use crate::sim::pipeline::NetworkSim;
        let net = crate::snn::fc_net("t", "mnist", &[16, 8, 4], 2, 2, 0.9, 3);
        let cfg = ExperimentConfig::new(net, HwConfig::with_lhr(vec![1, 1])).unwrap();
        let mut sim = NetworkSim::cost_only(&cfg, CostModel::default());
        let activity = vec![vec![2usize; 3], vec![1; 3], vec![1; 3]];
        let mut workload = ActivityWorkload::new(&activity, 2);
        let mut probe = CostCounter { calls: 0, total: 0 };
        let r = sim.run_engine(&mut workload, &mut probe);
        assert_eq!(probe.calls, 2 * 3, "one call per (layer, step)");
        assert_eq!(probe.total, r.serial_cycles, "hook sees every cost");
    }

    #[test]
    #[should_panic(expected = "activity needs input")]
    fn activity_arity_checked() {
        let activity = vec![vec![1usize; 3]; 2];
        let _ = ActivityWorkload::new(&activity, 3);
    }

    #[test]
    #[should_panic(expected = "same spike-train length")]
    fn batch_rejects_ragged_samples() {
        let a: SpikeTrain = vec![BitVec::zeros(4); 3];
        let b: SpikeTrain = vec![BitVec::zeros(4); 2];
        let inputs = vec![a, b];
        let _ = BatchWorkload::new(&inputs);
    }

    #[test]
    fn batch_workload_indexes_samples() {
        let mk = |bit: usize| -> SpikeTrain {
            (0..2)
                .map(|_| {
                    let mut v = BitVec::zeros(8);
                    v.set(bit);
                    v
                })
                .collect()
        };
        let inputs = vec![mk(1), mk(5)];
        let mut wl = BatchWorkload::new(&inputs);
        assert_eq!(wl.t_steps(), 4);
        let mut buf = BitVec::zeros(0);
        wl.begin_step(0, &mut buf);
        assert!(buf.get(1));
        wl.begin_step(3, &mut buf);
        assert!(buf.get(5) && !buf.get(1));
    }

    #[test]
    fn batch_decode_probe_decodes_per_sample() {
        let mut p = BatchDecodeProbe::new(2, 2, 2);
        // sample 0: class 1 pool spikes more
        let s0 = BitVec::from_bools(&[false, false, true, true]);
        p.on_network_output(0, &s0);
        p.on_network_output(1, &s0);
        // sample 1: class 0 pool spikes more
        let s1 = BitVec::from_bools(&[true, true, false, false]);
        p.on_network_output(2, &s1);
        p.on_network_output(3, &s1);
        assert_eq!(p.predictions, vec![Some(1), Some(0)]);
    }

    #[test]
    fn batch_decode_probe_records_per_sample_completions() {
        let mut p = BatchDecodeProbe::new(2, 2, 2);
        let s = BitVec::from_bools(&[true, false, false, false]);
        for t in 0..4 {
            p.on_network_output(t, &s);
            p.on_step_finish(t, (t as u64 + 1) * 10);
        }
        // sample boundaries fall after steps 1 and 3
        assert_eq!(p.completions, vec![20, 40]);
        assert_eq!(p.predictions.len(), 2);
    }
}
