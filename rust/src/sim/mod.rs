//! Cycle-accurate, sparsity-aware accelerator simulator — the paper's core
//! contribution, re-hosted from SystemC/TLM into deterministic Rust (see
//! DESIGN.md §Substitutions #5).
//!
//! Components mirror the paper's TLM platform (Fig. 3):
//! * [`penc`] — chunked priority encoder (spike-train compression, Fig. 4)
//! * [`neural_unit`] — logical-to-hardware neuron mapping (base address /
//!   neural size), serial accumulate + LIF activate
//! * [`memory`] — weight block allocation and port contention
//! * [`layer`] — one layer's ECU + NUs + memory, functional and cost-only
//! * [`engine`] — the unified pipelined scheduler: one finish-time
//!   recurrence parameterized by pluggable [`engine::Workload`]s
//!   (functional / activity / batched) and [`engine::Probe`]s (traces,
//!   per-sample decoding)
//! * [`pipeline`] — `NetworkSim`: layer construction + thin run-mode
//!   wrappers over the engine
//! * [`partitioned`] — `PartitionedNetworkSim`: multi-chip pipelining of
//!   `NetworkSim` instances over a [`crate::partition`] plan, with
//!   credit-based inter-chip links (ideal links reproduce the
//!   single-chip engine byte-identically)
//! * [`batch_kernel`] — bit-sliced batched execution: 64 samples per u64
//!   lane word, byte-identical to the per-sample engine on FC nets
//! * [`costs`] — the named cycle-cost coefficients in one auditable place
//! * [`stats`] — activity counters feeding the energy model and reports

pub mod batch_kernel;
pub mod costs;
pub mod dynamic;
pub mod ecu;
pub mod engine;
pub mod layer;
pub mod memory;
pub mod neural_unit;
pub mod partitioned;
pub mod penc;
pub mod pipeline;
pub mod stats;

pub use batch_kernel::{selects_sliced, BatchKernel, SLICED_AUTO_MIN_BATCH};
pub use costs::CostModel;
pub use dynamic::{compare_static_dynamic, fc_step_cost, DynamicAllocator, DynamicResult};
pub use ecu::{EcuFsm, EcuState};
pub use engine::{
    advance_finish, ActivityWorkload, BatchDecodeProbe, BatchWorkload, Engine, NullProbe, Probe,
    SpikeTrainWorkload, TeeProbe, TraceProbe, Workload,
};
pub use layer::{LayerSim, LayerWeights};
pub use memory::MemoryUnit;
pub use neural_unit::NuMap;
pub use partitioned::{LinkStats, PartitionedNetworkSim};
pub use penc::Penc;
pub use pipeline::{random_spike_train, random_weights, BatchOutcome, NetworkSim};
pub use stats::{decode_counts, LayerStats, PhaseCycles, SimResult};
