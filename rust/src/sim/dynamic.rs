//! Dynamic (run-time) sparsity-aware neuron allocation — the paper's §VII
//! future work ("we aim to implement a dynamic scheme of sparsity-aware
//! neuron allocation directly in hardware"), built here as a simulator
//! extension and evaluated as an ablation bench.
//!
//! Model: a single global pool of `budget` hardware neural units is
//! re-partitioned across layers **every time step**, proportionally to each
//! layer's incoming spike count (its imminent workload). Reconfiguration
//! costs `reconfig_cycles` per step (crossbar re-arm). Static allocation is
//! the degenerate case with one partition chosen up front.

use crate::sim::costs::CostModel;
use crate::sim::engine::advance_finish;
use crate::snn::{Layer, NetDef};
use anyhow::{bail, Result};

/// Dynamic allocator over a global NU budget.
#[derive(Debug, Clone)]
pub struct DynamicAllocator {
    pub budget: usize,
    /// Cycles charged per reallocation event.
    pub reconfig_cycles: u64,
}

impl DynamicAllocator {
    pub fn new(budget: usize) -> Self {
        DynamicAllocator {
            budget,
            reconfig_cycles: 8,
        }
    }

    /// Split the budget across parametric layers proportionally to their
    /// incoming spike counts (min 1 unit each). Returns units per
    /// parametric layer.
    pub fn allocate(&self, spikes_in: &[usize]) -> Vec<usize> {
        let n = spikes_in.len();
        assert!(n >= 1);
        assert!(self.budget >= n, "budget must cover 1 unit per layer");
        let total: usize = spikes_in.iter().sum::<usize>().max(1);
        let spare = self.budget - n;
        let mut units: Vec<usize> = spikes_in
            .iter()
            .map(|&s| 1 + spare * s / total)
            .collect();
        // Distribute the rounding remainder: an equal share to every layer
        // first (the remainder can approach the whole spare pool when the
        // spike counts are all zero), then one extra unit per layer in
        // busiest-first order until the budget is exhausted. Equivalent to
        // cycling busiest-first one unit at a time, but O(n) instead of
        // O(leftover) — and, unlike the old `take(n * 4)` cap, never drops
        // units when leftover > 4n.
        let leftover = self.budget - units.iter().sum::<usize>();
        let share = leftover / n;
        if share > 0 {
            for u in units.iter_mut() {
                *u += share;
            }
        }
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(spikes_in[i]));
        for &i in order.iter().take(leftover % n) {
            units[i] += 1;
        }
        units
    }
}

/// Per-step cost of one FC layer under an explicit unit count (the
/// cost-only FC formula with per_unit = ceil(n/units)). Public so the
/// runtime LHR controller in [`crate::events::adaptive`] prices steps
/// with exactly the ablation's formula.
pub fn fc_step_cost(
    n_pre: usize,
    n: usize,
    units: usize,
    s_in: usize,
    penc_width: usize,
    costs: &CostModel,
) -> u64 {
    let per_unit = n.div_ceil(units.max(1)) as u64;
    let chunks = n_pre.div_ceil(penc_width) as u64;
    costs.penc_chunk * chunks
        + costs.penc_per_spike * s_in as u64
        + s_in as u64 * per_unit * costs.fc_accum
        + per_unit * costs.act_fc
        + costs.phase_overhead
}

/// Outcome of a static-vs-dynamic comparison.
#[derive(Debug, Clone)]
pub struct DynamicResult {
    pub static_cycles: u64,
    pub dynamic_cycles: u64,
    pub budget: usize,
}

impl DynamicResult {
    pub fn speedup(&self) -> f64 {
        self.static_cycles as f64 / self.dynamic_cycles as f64
    }
}

/// Compare static proportional allocation (fixed partition sized by *mean*
/// activity) against per-step dynamic allocation, on an FC network with
/// per-step activity `activity[stage][t]` (input + per layer, as produced
/// by `data::ActivityModel::sample`). Pipelined latency for both.
///
/// Errors on non-FC layers (the ablation's allocation unit is the FC
/// neural unit) and on an empty spike train — a `t_steps` of zero would
/// otherwise NaN-cast every mean activity to 0.
pub fn compare_static_dynamic(
    net: &NetDef,
    activity: &[Vec<usize>],
    budget: usize,
    costs: &CostModel,
) -> Result<DynamicResult> {
    let mut fc: Vec<(usize, usize)> = Vec::with_capacity(net.layers.len());
    for (i, l) in net.layers.iter().enumerate() {
        match l {
            Layer::Fc { n_pre, n } => fc.push((*n_pre, *n)),
            other => bail!(
                "dynamic allocation ablation covers FC networks only, but layer {i} \
                 of '{}' is a {} layer",
                net.name,
                other.kind_str()
            ),
        }
    }
    let n_layers = fc.len();
    if activity.len() < n_layers {
        bail!(
            "activity has {} stages but '{}' needs {} (input + one per layer but the last)",
            activity.len(),
            net.name,
            n_layers
        );
    }
    let t_steps = activity[0].len();
    if t_steps == 0 {
        bail!(
            "empty spike train: the activity for '{}' has 0 time steps, so mean \
             activity is undefined",
            net.name
        );
    }
    let alloc = DynamicAllocator::new(budget);

    // static: allocate once from mean activity
    let means: Vec<usize> = (0..n_layers)
        .map(|l| {
            (activity[l].iter().sum::<usize>() as f64 / t_steps as f64).round() as usize
        })
        .collect();
    let static_units = alloc.allocate(&means);

    let mut static_finish = vec![0u64; n_layers];
    let mut dynamic_finish = vec![0u64; n_layers];
    for t in 0..t_steps {
        let spikes_t: Vec<usize> = (0..n_layers).map(|l| activity[l][t]).collect();
        let dyn_units = alloc.allocate(&spikes_t);
        let mut prev_s = 0u64;
        let mut prev_d = 0u64;
        for l in 0..n_layers {
            let (n_pre, n) = fc[l];
            let s_in = spikes_t[l];
            let cs = fc_step_cost(n_pre, n, static_units[l], s_in, 64, costs);
            let cd = fc_step_cost(n_pre, n, dyn_units[l], s_in, 64, costs)
                + alloc.reconfig_cycles;
            prev_s = advance_finish(&mut static_finish[l], prev_s, cs);
            prev_d = advance_finish(&mut dynamic_finish[l], prev_d, cd);
        }
    }
    Ok(DynamicResult {
        static_cycles: *static_finish.last().unwrap(),
        dynamic_cycles: *dynamic_finish.last().unwrap(),
        budget,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ActivityModel;
    use crate::snn::table1_net;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    #[test]
    fn allocation_exhausts_budget_and_covers_layers() {
        let a = DynamicAllocator::new(100);
        let u = a.allocate(&[90, 5, 5]);
        assert_eq!(u.iter().sum::<usize>(), 100);
        assert!(u.iter().all(|&x| x >= 1));
        assert!(u[0] > u[1] && u[0] > u[2], "busiest layer gets most: {u:?}");
    }

    #[test]
    fn zero_activity_still_valid() {
        let a = DynamicAllocator::new(8);
        let u = a.allocate(&[0, 0, 0]);
        assert_eq!(u.iter().sum::<usize>(), 8);
        assert!(u.iter().all(|&x| x >= 1));
    }

    #[test]
    fn prop_allocation_invariants() {
        prop_check(128, 0xDA11, |g| {
            let n = g.usize_in(1, 8);
            let budget = g.usize_in(n, 500);
            let spikes: Vec<usize> = (0..n).map(|_| g.usize_in(0, 1000)).collect();
            let u = DynamicAllocator::new(budget).allocate(&spikes);
            if u.iter().sum::<usize>() != budget {
                return Err(format!("budget not exhausted: {u:?} vs {budget}"));
            }
            if u.iter().any(|&x| x == 0) {
                return Err("layer starved".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sum_invariant_with_large_remainders() {
        // Regression: the remainder loop used to stop after `n * 4`
        // hand-outs, silently dropping units whenever leftover > 4n. The
        // worst case is all-zero spike counts (the entire spare pool is
        // remainder) with a budget far above 5n — cover that regime plus
        // heavily skewed counts across random large budgets.
        prop_check(256, 0x5D0B, |g| {
            let n = g.usize_in(1, 8);
            let budget = g.usize_in(n, 100_000);
            let spikes: Vec<usize> = (0..n)
                .map(|_| if g.usize_in(0, 2) == 0 { 0 } else { g.usize_in(1, 1 << 20) })
                .collect();
            let u = DynamicAllocator::new(budget).allocate(&spikes);
            if u.iter().sum::<usize>() != budget {
                return Err(format!(
                    "sum(units) {} != budget {budget} for spikes {spikes:?}: {u:?}",
                    u.iter().sum::<usize>()
                ));
            }
            if u.iter().any(|&x| x == 0) {
                return Err("layer starved".into());
            }
            Ok(())
        });
        // the deterministic worst case spelled out: leftover = 9996 > 4n
        let u = DynamicAllocator::new(10_000).allocate(&[0, 0, 0, 0]);
        assert_eq!(u.iter().sum::<usize>(), 10_000);
    }

    #[test]
    fn non_fc_layer_is_a_descriptive_error_not_a_panic() {
        // regression: conv/pool nets fed to the ablation used to panic
        let net = table1_net("net5");
        let activity = vec![vec![10usize; 4]; net.layers.len()];
        let err = compare_static_dynamic(&net, &activity, 64, &CostModel::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("conv"), "error must name the layer kind: {err}");
        assert!(err.contains("net5"), "error must name the net: {err}");
    }

    #[test]
    fn empty_spike_train_is_an_error_not_nan_zero() {
        // regression: t_steps == 0 NaN-cast every mean activity to 0 and
        // produced a bogus 0-cycle comparison instead of failing
        let net = table1_net("net1");
        let activity = vec![Vec::<usize>::new(); 4];
        let err = compare_static_dynamic(&net, &activity, 64, &CostModel::default())
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("0 time steps"),
            "error must describe the empty train: {err}"
        );
    }

    #[test]
    fn dynamic_beats_static_on_bursty_traffic() {
        // Alternating bursts between layers: static splits the pool evenly,
        // dynamic follows the burst — dynamic must win despite reconfig.
        let net = table1_net("net1");
        let t = 40;
        let mut activity = vec![vec![0usize; t]; 4];
        for step in 0..t {
            activity[0][step] = if step % 2 == 0 { 400 } else { 5 };
            activity[1][step] = if step % 2 == 0 { 5 } else { 400 };
            activity[2][step] = 10;
            activity[3][step] = 5;
        }
        let r = compare_static_dynamic(&net, &activity, 64, &CostModel::default()).unwrap();
        assert!(
            r.speedup() > 1.05,
            "dynamic should win on bursty traffic: x{:.3}",
            r.speedup()
        );
    }

    #[test]
    fn static_competitive_on_stationary_traffic() {
        // With stationary activity the static partition is near-optimal and
        // dynamic only pays reconfiguration: speedup ~<= 1.
        let net = table1_net("net1");
        let model = ActivityModel::for_net(&net);
        let mut rng = Rng::new(3);
        let activity = model.sample(40, &mut rng);
        let r = compare_static_dynamic(&net, &activity, 64, &CostModel::default()).unwrap();
        assert!(
            r.speedup() < 1.1,
            "stationary traffic shouldn't favor dynamic much: x{:.3}",
            r.speedup()
        );
    }
}
