//! Neural Unit (NU) model (paper §V-C).
//!
//! Each NU owns a contiguous range of logical neurons — `base_addr` to
//! `base_addr + neural_size` — for FC layers, or a range of output channels
//! for CONV layers. During accumulation the NU serially walks its assigned
//! neurons per incoming spike address; during activation it serially
//! applies the LIF update. NUs across a layer run in parallel, so the
//! layer's phase time is the *maximum* over NUs, which the mapping below
//! makes `ceil(n / n_units)` (balanced partition).

/// The mapping of logical neurons (or conv output channels) onto hardware
/// neural units for one layer.
#[derive(Debug, Clone)]
pub struct NuMap {
    /// Logical units (neurons / output channels).
    pub logical: usize,
    /// Hardware NUs instantiated.
    pub units: usize,
}

impl NuMap {
    /// Build from the LHR knob: `units = ceil(logical / lhr)`.
    pub fn from_lhr(logical: usize, lhr: usize) -> Self {
        assert!(lhr >= 1, "LHR must be >= 1");
        let lhr = lhr.min(logical.max(1));
        NuMap {
            logical,
            units: logical.div_ceil(lhr).max(1),
        }
    }

    /// Worst-case logical neurons per NU — the serial depth of each phase.
    pub fn per_unit(&self) -> usize {
        self.logical.div_ceil(self.units)
    }

    /// (base_addr, neural_size) of unit `u` — the module parameters the
    /// hardware generator writes into each NU instance.
    pub fn range(&self, u: usize) -> (usize, usize) {
        let per = self.per_unit();
        let base = u * per;
        let size = per.min(self.logical.saturating_sub(base));
        (base, size)
    }

    /// Which NU serves logical neuron `i`.
    pub fn unit_of(&self, i: usize) -> usize {
        i / self.per_unit()
    }

    /// Effective LHR realized by the mapping (>= requested when rounding).
    pub fn effective_lhr(&self) -> usize {
        self.per_unit()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn lhr_one_is_fully_parallel() {
        let m = NuMap::from_lhr(500, 1);
        assert_eq!(m.units, 500);
        assert_eq!(m.per_unit(), 1);
        assert_eq!(m.range(499), (499, 1));
    }

    #[test]
    fn lhr_divides_units() {
        let m = NuMap::from_lhr(500, 4);
        assert_eq!(m.units, 125);
        assert_eq!(m.per_unit(), 4);
        assert_eq!(m.range(0), (0, 4));
        assert_eq!(m.range(124), (496, 4));
    }

    #[test]
    fn ragged_tail_handled() {
        let m = NuMap::from_lhr(10, 4); // units = 3, per = 4, last gets 2
        assert_eq!(m.units, 3);
        assert_eq!(m.range(2), (8, 2));
        assert_eq!(m.unit_of(9), 2);
    }

    #[test]
    fn lhr_capped_at_layer_size() {
        let m = NuMap::from_lhr(8, 64); // time-multiplexed single NU
        assert_eq!(m.units, 1);
        assert_eq!(m.per_unit(), 8);
    }

    #[test]
    fn prop_partition_covers_all_neurons() {
        prop_check(256, 0x4A11, |g| {
            let logical = g.usize_in(1, 4096);
            let lhr = g.pow2(8);
            let m = NuMap::from_lhr(logical, lhr);
            // every logical neuron belongs to exactly one in-range unit
            let mut covered = 0usize;
            for u in 0..m.units {
                let (base, size) = m.range(u);
                if base + size > logical && size > 0 {
                    return Err(format!("range {u} spills: {base}+{size}>{logical}"));
                }
                covered += size;
            }
            if covered != logical {
                return Err(format!("covered {covered} != logical {logical}"));
            }
            // unit_of agrees with range()
            for &probe in &[0, logical / 2, logical - 1] {
                let u = m.unit_of(probe);
                let (base, size) = m.range(u);
                if probe < base || probe >= base + size {
                    return Err(format!("unit_of({probe}) = {u} out of its range"));
                }
            }
            // serial depth never exceeds requested LHR
            if m.per_unit() > lhr.min(logical) {
                return Err(format!(
                    "per_unit {} > lhr {}",
                    m.per_unit(),
                    lhr.min(logical)
                ));
            }
            Ok(())
        });
    }
}
