//! Bit-sliced batch execution: advance up to 64 batch samples per u64
//! word op (ROADMAP item: batch-level bit-slicing).
//!
//! The per-sample engine streams samples back-to-back through the layer
//! pipeline, so a batch of B costs B full engine passes — every spike-word
//! scan, buffer copy and per-step dispatch repeats per sample. This kernel
//! transposes the batch into [`BitMat`] lane words (bit `b` = sample `b`)
//! and runs each `(step, layer)` once for the whole lane group:
//!
//! * **Compress**: one occupancy-word scan over the pre-neurons builds all
//!   64 lanes' spike address lists together — a neuron inactive in *every*
//!   sample costs one word test for the whole batch, which is where
//!   sparsity pays 64x instead of 1x.
//! * **Accumulate**: per lane, the exact `fc_accumulate` fused-quad row
//!   walk of the per-sample path (`sim::layer`). f32 addition is not
//!   associative, so the per-lane operation *order* is shared by
//!   construction rather than re-derived — this is what keeps membranes,
//!   spikes and therefore predictions byte-identical.
//! * **Activate**: lane-parallel LIF with the same leak/threshold/soft-reset
//!   op order as `LifState::activate`, fused with the accumulator clear and
//!   packing spikes straight into lane rows (no bool scratch, no
//!   `fill_from_bools` pass); a 64x64 bit transpose turns those rows into
//!   the next layer's lane words.
//!
//! Cycle accounting is *replayed*, not re-derived: every FC cost and
//! `LayerStats` field is a pure function of each step's `(in_spikes,
//! fired)` pair, so the kernel records those counts during the functional
//! sweep and feeds them through the shared `LayerSim::fc_account` +
//! [`advance_finish`] recurrence in the per-sample step order. The
//! per-sample path is the differential oracle (see
//! `rust/tests/fuzz_differential.rs`, sliced lane).
//!
//! Scope: all-FC topologies (the paper's net1–net4 MLPs). Conv/pool nets
//! fall back to the per-sample engine — selection is centralized in
//! [`selects_sliced`].

use crate::sim::engine::advance_finish;
use crate::sim::layer::fc_accumulate;
use crate::sim::pipeline::{BatchOutcome, NetworkSim};
use crate::sim::stats::{decode_counts, SimResult};
use crate::snn::{BitMat, Layer, NetDef, SpikeTrain};

/// Which batched execution path [`NetworkSim::run_batched_timed_with`]
/// takes. Both kernels produce byte-identical results; the choice is
/// purely a throughput decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchKernel {
    /// Pick [`BatchKernel::Sliced`] when the topology is all-FC and the
    /// batch has at least [`SLICED_AUTO_MIN_BATCH`] samples.
    #[default]
    Auto,
    /// Force the bit-sliced kernel (still falls back on conv/pool nets,
    /// which it does not implement).
    Sliced,
    /// Force the per-sample engine (the differential oracle).
    PerSample,
}

impl BatchKernel {
    /// Parse the CLI spelling (`--kernel auto|sliced|per-sample`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(BatchKernel::Auto),
            "sliced" => Ok(BatchKernel::Sliced),
            "per-sample" => Ok(BatchKernel::PerSample),
            _ => Err(format!(
                "unknown batch kernel '{s}' (expected auto, sliced or per-sample)"
            )),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            BatchKernel::Auto => "auto",
            BatchKernel::Sliced => "sliced",
            BatchKernel::PerSample => "per-sample",
        }
    }
}

/// Batch size at which [`BatchKernel::Auto`] switches to the sliced
/// kernel: the shared occupancy scan and transpose amortize across lanes,
/// and by ~half a lane word of samples they clearly beat the per-sample
/// engine's per-step overheads. Serving batches (`BatchPolicy::max_batch`)
/// of 8+ therefore get the sliced path transparently.
pub const SLICED_AUTO_MIN_BATCH: usize = 8;

/// Centralized kernel selection: the sliced path handles all-FC
/// topologies only; anything else (or a batch below the auto threshold)
/// runs per-sample.
pub fn selects_sliced(kernel: BatchKernel, batch: usize, net: &NetDef) -> bool {
    let fc_only =
        !net.layers.is_empty() && net.layers.iter().all(|l| matches!(l, Layer::Fc { .. }));
    match kernel {
        BatchKernel::PerSample => false,
        BatchKernel::Sliced => fc_only,
        BatchKernel::Auto => fc_only && batch >= SLICED_AUTO_MIN_BATCH,
    }
}

/// Per-layer lane-major functional state for one lane group: `lanes`
/// contiguous accumulator/membrane blocks of `n`, plus each lane's packed
/// output spike row.
struct LaneState {
    acc: Vec<f32>,
    v: Vec<f32>,
    rows: Vec<u64>,
    words_per_lane: usize,
}

/// Bit-sliced batched run. Caller (`run_batched_timed_with`) has already
/// checked [`selects_sliced`]; this panics on non-FC layers.
///
/// Functional layer state is reset on exit (batched runs reset state at
/// every sample boundary anyway, so no later result can depend on it).
pub(crate) fn run_sliced(
    sim: &mut NetworkSim,
    inputs: &[SpikeTrain],
) -> (SimResult, Vec<BatchOutcome>) {
    // mirror BatchWorkload::new's validation so both kernels reject the
    // same malformed batches with the same messages
    assert!(!inputs.is_empty(), "batch needs at least one sample");
    let t_per_sample = inputs[0].len();
    assert!(t_per_sample > 0, "samples must span at least one step");
    assert!(
        inputs.iter().all(|s| s.len() == t_per_sample),
        "all batch samples must share the same spike-train length"
    );

    let n_layers = sim.layers.len();
    let batch = inputs.len();
    let out_bits = sim.net.layers.last().map(|l| l.output_bits()).unwrap_or(0);
    let (classes, population) = (sim.net.classes, sim.net.population);

    // per-(layer, sample, step) spike counts feeding the accounting replay
    let cell = |l: usize, sample: usize, tau: usize| (l * batch + sample) * t_per_sample + tau;
    let mut in_cnt = vec![0u32; n_layers * batch * t_per_sample];
    let mut fired_cnt = vec![0u32; n_layers * batch * t_per_sample];

    let mut output_counts = vec![0u32; out_bits];
    let mut predictions: Vec<Option<usize>> = Vec::with_capacity(batch);

    // ---- functional sweep, one lane group (<= 64 samples) at a time ----
    for (g, group) in inputs.chunks(64).enumerate() {
        let lanes = group.len();
        let mat = BitMat::pack(group);
        debug_assert_eq!(mat.neurons(), sim.net.input_bits, "input width mismatch");

        let mut state: Vec<LaneState> = sim
            .layers
            .iter()
            .map(|layer| {
                let view = layer.fc_view().expect("sliced kernel requires an all-FC net");
                let wpl = view.n.div_ceil(64);
                LaneState {
                    acc: vec![0.0; lanes * view.n],
                    v: vec![0.0; lanes * view.n],
                    rows: vec![0u64; lanes * wpl],
                    words_per_lane: wpl,
                }
            })
            .collect();
        // one lane-word matrix per layer output, reused across steps
        let mut carries: Vec<BitMat> = sim
            .layers
            .iter()
            .map(|layer| BitMat::zeros(1, layer.fc_view().unwrap().n, lanes))
            .collect();
        let mut addrs: Vec<Vec<u32>> = vec![Vec::new(); lanes];
        let mut lane_counts = vec![0u32; lanes * out_bits];

        for tau in 0..t_per_sample {
            for l in 0..n_layers {
                let view = sim.layers[l].fc_view().unwrap();
                // shared compress: one occupancy-word scan distributes
                // ascending pre-neuron addresses to every active lane
                for a in addrs.iter_mut() {
                    a.clear();
                }
                let (src, t_src): (&BitMat, usize) =
                    if l == 0 { (&mat, tau) } else { (&carries[l - 1], 0) };
                src.for_each_active_lane(t_src, |i, w| {
                    let mut w = w;
                    while w != 0 {
                        addrs[w.trailing_zeros() as usize].push(i as u32);
                        w &= w - 1;
                    }
                });

                let st = &mut state[l];
                let is_last = l + 1 == n_layers;
                for (lane, alist) in addrs.iter().enumerate() {
                    let s = alist.len();
                    let acc = &mut st.acc[lane * view.n..(lane + 1) * view.n];
                    fc_accumulate(acc, view.w, view.n, alist);
                    // fused LIF activate + accumulator clear + bit pack.
                    // The f32 expression matches `LifState::activate`'s hot
                    // path term for term; clearing acc when s == 0 writes
                    // 0.0 over 0.0 (the per-sample path merely skips the
                    // redundant pass), so values stay identical.
                    let v = &mut st.v[lane * view.n..(lane + 1) * view.n];
                    let row = &mut st.rows[lane * st.words_per_lane..(lane + 1) * st.words_per_lane];
                    let (beta, theta) = (view.beta, view.theta);
                    let mut fired = 0usize;
                    let mut word = 0u64;
                    for (j, ((v, a), &b)) in
                        v.iter_mut().zip(acc.iter_mut()).zip(view.b).enumerate()
                    {
                        let v_new = beta * *v + *a + b;
                        let spike = v_new >= theta;
                        *v = if spike { v_new - theta } else { v_new };
                        *a = 0.0;
                        fired += spike as usize;
                        word |= (spike as u64) << (j & 63);
                        if j & 63 == 63 {
                            row[j >> 6] = word;
                            word = 0;
                        }
                    }
                    if view.n & 63 != 0 {
                        row[view.n >> 6] = word;
                    }
                    let sample = g * 64 + lane;
                    in_cnt[cell(l, sample, tau)] = s as u32;
                    fired_cnt[cell(l, sample, tau)] = fired as u32;
                    if is_last {
                        // network output: global spike accumulation plus the
                        // per-sample population counts the decode reads
                        let counts = &mut lane_counts[lane * out_bits..(lane + 1) * out_bits];
                        for (wj, &rw) in row.iter().enumerate() {
                            let mut rw = rw;
                            while rw != 0 {
                                let idx = (wj << 6) + rw.trailing_zeros() as usize;
                                counts[idx] += 1;
                                output_counts[idx] += 1;
                                rw &= rw - 1;
                            }
                        }
                    }
                }
                if !is_last {
                    carries[l].fill_from_lane_rows(&st.rows);
                }
            }
        }
        for lane in 0..lanes {
            predictions.push(decode_counts(
                &lane_counts[lane * out_bits..(lane + 1) * out_bits],
                classes,
                population,
            ));
        }
    }

    // ---- accounting replay in the per-sample engine's step order ----
    // (all LayerStats fields are order-independent sums/maxes, but the
    // pipelined finish-time recurrence is not — replay it exactly)
    let mut finish = vec![0u64; n_layers];
    let mut serial = 0u64;
    let mut completions: Vec<u64> = Vec::with_capacity(batch);
    for sample in 0..batch {
        for tau in 0..t_per_sample {
            let mut prev_finish = 0u64;
            for (l, layer) in sim.layers.iter_mut().enumerate() {
                let phases = layer.fc_account(
                    in_cnt[cell(l, sample, tau)] as usize,
                    fired_cnt[cell(l, sample, tau)] as usize,
                );
                serial += phases.total();
                prev_finish = advance_finish(&mut finish[l], prev_finish, phases.total());
            }
            if tau + 1 == t_per_sample {
                completions.push(*finish.last().unwrap());
            }
        }
    }

    for layer in &mut sim.layers {
        layer.reset_state();
    }

    let result = SimResult {
        total_cycles: finish.last().copied().unwrap_or(0),
        serial_cycles: serial,
        per_layer: sim.layers.iter().map(|l| l.stats.clone()).collect(),
        t_steps: batch * t_per_sample,
        output_counts,
        predicted_class: None,
    };
    let outcomes = predictions
        .into_iter()
        .zip(completions)
        .map(|(prediction, completion_cycles)| BatchOutcome {
            prediction,
            completion_cycles,
        })
        .collect();
    (result, outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, HwConfig};
    use crate::sim::{random_spike_train, CostModel, NetworkSim};
    use crate::snn::{fc_net, table1_net};
    use crate::util::rng::Rng;

    fn fc_sim(t_steps: usize) -> NetworkSim {
        let net = fc_net("bk", "mnist", &[48, 33, 10], 5, 2, 0.9, t_steps);
        let cfg = ExperimentConfig::new(net, HwConfig::with_lhr(vec![3, 2])).unwrap();
        NetworkSim::with_random_weights(&cfg, 11, CostModel::default())
    }

    fn run_both(
        mk: impl Fn() -> NetworkSim,
        inputs: &[crate::snn::SpikeTrain],
    ) -> (
        (SimResult, Vec<BatchOutcome>),
        (SimResult, Vec<BatchOutcome>),
    ) {
        let mut a = mk();
        let mut b = mk();
        (
            a.run_batched_timed_with(inputs, BatchKernel::PerSample),
            b.run_batched_timed_with(inputs, BatchKernel::Sliced),
        )
    }

    fn assert_identical(ps: &(SimResult, Vec<BatchOutcome>), sl: &(SimResult, Vec<BatchOutcome>)) {
        assert_eq!(ps.1, sl.1, "per-sample outcomes diverge");
        assert_eq!(ps.0.total_cycles, sl.0.total_cycles);
        assert_eq!(ps.0.serial_cycles, sl.0.serial_cycles);
        assert_eq!(ps.0.t_steps, sl.0.t_steps);
        assert_eq!(ps.0.output_counts, sl.0.output_counts);
        assert_eq!(
            format!("{:?}", ps.0.per_layer),
            format!("{:?}", sl.0.per_layer),
            "LayerStats diverge"
        );
    }

    #[test]
    fn sliced_matches_per_sample_across_group_boundaries() {
        let mut rng = Rng::new(42);
        for batch in [1usize, 5, 63, 64, 65, 130] {
            let inputs: Vec<_> = (0..batch)
                .map(|_| random_spike_train(48, 4, 0.25, &mut rng))
                .collect();
            let (ps, sl) = run_both(|| fc_sim(4), &inputs);
            assert_identical(&ps, &sl);
        }
    }

    #[test]
    fn sliced_matches_on_fc_table1_nets() {
        // trimmed step counts keep the unit test fast; the bench covers
        // full-length runs
        let mut rng = Rng::new(7);
        for name in ["net1", "net2", "net3", "net4"] {
            let mut net = table1_net(name);
            if !net.layers.iter().all(|l| matches!(l, Layer::Fc { .. })) {
                continue;
            }
            net.t_steps = 2;
            let lhr = vec![4; net.parametric_layers().len()];
            let cfg = ExperimentConfig::new(net.clone(), HwConfig::with_lhr(lhr)).unwrap();
            let inputs: Vec<_> = (0..9)
                .map(|_| random_spike_train(net.input_bits, net.t_steps, 0.12, &mut rng))
                .collect();
            let mk = || NetworkSim::with_random_weights(&cfg, 3, CostModel::default());
            let (ps, sl) = run_both(mk, &inputs);
            assert_identical(&ps, &sl);
        }
    }

    #[test]
    fn auto_threshold_and_topology_gate_selection() {
        let fc = fc_net("a", "d", &[8, 4], 4, 1, 0.9, 3);
        assert!(!selects_sliced(BatchKernel::Auto, SLICED_AUTO_MIN_BATCH - 1, &fc));
        assert!(selects_sliced(BatchKernel::Auto, SLICED_AUTO_MIN_BATCH, &fc));
        assert!(selects_sliced(BatchKernel::Sliced, 1, &fc));
        assert!(!selects_sliced(BatchKernel::PerSample, 1000, &fc));
        let conv = table1_net("net5");
        assert!(!selects_sliced(BatchKernel::Sliced, 1000, &conv));
    }

    #[test]
    fn kernel_parse_roundtrip() {
        for k in [BatchKernel::Auto, BatchKernel::Sliced, BatchKernel::PerSample] {
            assert_eq!(BatchKernel::parse(k.as_str()).unwrap(), k);
        }
        assert!(BatchKernel::parse("fast").is_err());
    }
}
