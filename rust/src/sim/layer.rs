//! Per-layer functional + cycle-accurate simulation.
//!
//! A `LayerSim` couples the paper's three hardware components — ECU
//! (compression + phase sequencing), Neural Units (serial accumulate /
//! activate), Memory Unit (weight blocks + contention) — for one network
//! layer. It is *functional*: membrane potentials and output spikes are
//! computed exactly (bit-matched to the Python oracle) while every phase is
//! charged cycles per the `CostModel`. A cost-only path
//! (`step_cost_only`) supports activity-driven simulation where only spike
//! *counts* are known (used for calibrated DVS workloads and fast DSE).
//!
//! The functional step is the simulator's hot path and is event-driven
//! end to end: input spikes are decoded by raw-`u64` word scans
//! (`BitVec::for_each_one`), FC weight rows accumulate four-at-a-time
//! through bounds-check-free slices in the scalar oracle's exact f32
//! order, and the conv activation takes a touched-set sparse walk with
//! lazy leak replay behind a per-step density threshold
//! (`CONV_SPARSE_DENSITY_DIV`) instead of an unconditional dense sweep +
//! dense accumulator clear. All of it is byte-identical to
//! the preserved scalar step in [`crate::baselines::scalar`] — enforced
//! by the differential fuzz suite (`rust/tests/fuzz_differential.rs`).

use crate::sim::costs::CostModel;
use crate::sim::memory::MemoryUnit;
use crate::sim::neural_unit::NuMap;
use crate::sim::penc::Penc;
use crate::sim::stats::{LayerStats, PhaseCycles};
use crate::snn::{BitVec, Layer, LifState};

/// Weights for one parametric layer (row-major, matching the Python dump).
#[derive(Debug, Clone)]
pub enum LayerWeights {
    /// FC: `w[a * n + j]` = weight from pre-synaptic `a` to neuron `j`.
    Fc { w: Vec<f32>, b: Vec<f32> },
    /// Conv (HWIO): `w[((dy*k + dx)*cin + ci)*cout + oc]`.
    Conv { w: Vec<f32>, b: Vec<f32> },
    /// Pool layers carry no parameters.
    None,
}

/// One layer of the simulated accelerator.
pub struct LayerSim {
    pub layer: Layer,
    pub nu: NuMap,
    pub mem: MemoryUnit,
    pub penc: Penc,
    pub stats: LayerStats,
    costs: CostModel,
    lif: LifState,
    weights: LayerWeights,
    /// Accumulation buffer (one slot per logical neuron).
    acc: Vec<f32>,
    /// Conv: indices touched this step (event-driven activation set).
    touched: Vec<u32>,
    touched_flag: Vec<bool>,
    /// Scratch: compressed spike addresses (the shift-register contents).
    addr_buf: Vec<u32>,
    /// Scratch: output spikes as bools before packing.
    spike_buf: Vec<bool>,
    /// Conv lazy-leak bookkeeping for the touched-set sparse activation
    /// path (see `step_conv`): per-fmap-position count of steps fully
    /// applied, the layer's completed-step counter, positions whose
    /// residual membrane (any channel) can fire without input next step,
    /// and whether the last dense sweep left such a residual anywhere.
    synced_steps: Vec<u64>,
    steps_done: u64,
    hot: Vec<u32>,
    hot_scratch: Vec<u32>,
    dense_residual: bool,
    /// Sparse activation is legal at all: conv layer with all-zero biases,
    /// `0 <= beta <= 1` and `theta > 0` — the regime where an untouched,
    /// sub-threshold neuron provably cannot fire.
    lazy_leak_ok: bool,
}

/// Borrowed FC layer internals for the bit-sliced batch kernel
/// (`sim::batch_kernel`): enough to replicate `step_fc`'s functional
/// arithmetic per lane without exposing `LayerSim`'s fields.
pub(crate) struct FcView<'a> {
    pub n_pre: usize,
    pub n: usize,
    /// Row-major weights: `w[a * n + j]`.
    pub w: &'a [f32],
    pub b: &'a [f32],
    pub beta: f32,
    pub theta: f32,
}

/// FC weight-row accumulation over a compressed spike address list.
/// Four rows per pass over the accumulators, fused as two pairwise adds in
/// sequence — element-wise the exact f32 operation order of the scalar
/// oracle's back-to-back pairwise passes (`baselines::scalar`), so results
/// stay bit-identical while the accumulator read/write traffic halves
/// again. Slices elide bounds checks (§Perf #4). Shared verbatim by the
/// per-sample `step_fc` and the bit-sliced batch kernel's per-lane
/// accumulate, which keeps the two paths' f32 sequences identical by
/// construction.
pub(crate) fn fc_accumulate(acc: &mut [f32], w: &[f32], n: usize, addrs: &[u32]) {
    let mut quads = addrs.chunks_exact(4);
    for q in &mut quads {
        let (a0, a1) = (q[0] as usize, q[1] as usize);
        let (a2, a3) = (q[2] as usize, q[3] as usize);
        let r0 = &w[a0 * n..a0 * n + n];
        let r1 = &w[a1 * n..a1 * n + n];
        let r2 = &w[a2 * n..a2 * n + n];
        let r3 = &w[a3 * n..a3 * n + n];
        for ((((acc, &w0), &w1), &w2), &w3) in
            acc.iter_mut().zip(r0).zip(r1).zip(r2).zip(r3)
        {
            let t = *acc + (w0 + w1);
            *acc = t + (w2 + w3);
        }
    }
    let mut pairs = quads.remainder().chunks_exact(2);
    for pair in &mut pairs {
        let (a0, a1) = (pair[0] as usize, pair[1] as usize);
        let r0 = &w[a0 * n..a0 * n + n];
        let r1 = &w[a1 * n..a1 * n + n];
        for ((acc, &w0), &w1) in acc.iter_mut().zip(r0).zip(r1) {
            *acc += w0 + w1;
        }
    }
    for &a in pairs.remainder() {
        let row = &w[a as usize * n..(a as usize + 1) * n];
        for (acc, &wv) in acc.iter_mut().zip(row) {
            *acc += wv;
        }
    }
}

/// Sum over all feature-map positions of the number of in-range kernel
/// taps under 'same' padding — `sum_{y,x} |clipped footprint(y,x)|`.
/// The footprint factorizes into independent row and column tap counts,
/// so the sum is `Sy * Sx`. Dividing by `h*w` gives the mean clipped
/// footprint of a uniformly placed spike; the cost-only conv path charges
/// memory traffic with that expectation, matching the functional path's
/// exact border clipping on average.
pub fn conv_clipped_taps_sum(kernel: usize, height: usize, width: usize) -> u64 {
    let pad = (kernel - 1) / 2;
    let axis = |n: usize| -> u64 {
        (0..n)
            .map(|y| {
                // taps d with 0 <= y + pad - d < n, clamped to [0, k)
                let hi = (y + pad).min(kernel - 1);
                let lo = (y + pad + 1).saturating_sub(n);
                (hi + 1 - lo) as u64
            })
            .sum()
    };
    axis(height) * axis(width)
}

/// Visit one feature-map position on the conv sparse activation path:
/// replay `stale` deferred pure-leak steps (bit-identical to the oracle's
/// dense updates on an untouched, bias-free position), then apply the
/// current step's leak + integrate + threshold + soft reset for every
/// output channel, setting fired bits in `out` directly. Returns the
/// spike count and whether any channel's residual membrane can fire
/// without input next step (`v >= theta`).
#[inline]
fn lazy_visit_pos(
    v: &mut [f32],
    acc: &[f32],
    out: &mut BitVec,
    p: usize,
    (fmap, out_ch): (usize, usize),
    (beta, theta): (f32, f32),
    stale: u64,
) -> (usize, bool) {
    let mut fired = 0usize;
    let mut hot = false;
    for oc in 0..out_ch {
        let i = oc * fmap + p;
        let mut vi = v[i];
        for _ in 0..stale {
            // the oracle's untouched-position update with acc = bias = 0
            vi = beta * vi + 0.0 + 0.0;
        }
        let v_new = beta * vi + acc[i] + 0.0;
        let spike = v_new >= theta;
        vi = if spike { v_new - theta } else { v_new };
        if spike {
            out.set(i);
            fired += 1;
        }
        hot |= vi >= theta;
        v[i] = vi;
    }
    (fired, hot)
}

/// Panic unless `weights` matches `layer`'s shape exactly. A bias vector
/// shorter than the output width used to be silently zero-filled in the
/// conv hot loop (`b.get(oc).unwrap_or(0.0)`), turning a construction
/// mistake into quietly wrong membrane arithmetic; shape errors must
/// surface when the layer is built, not as a wrong answer later.
fn validate_weights(index: usize, layer: &Layer, weights: &LayerWeights) {
    match (layer, weights) {
        (Layer::Fc { n_pre, n }, LayerWeights::Fc { w, b }) => {
            assert_eq!(
                w.len(),
                n_pre * n,
                "fc{index}: weight matrix has {} entries, expected {n_pre}x{n}",
                w.len()
            );
            assert_eq!(
                b.len(),
                *n,
                "fc{index}: bias vector has {} entries, expected one per neuron ({n})",
                b.len()
            );
        }
        (
            Layer::Conv {
                in_ch,
                out_ch,
                kernel,
                ..
            },
            LayerWeights::Conv { w, b },
        ) => {
            assert_eq!(
                w.len(),
                kernel * kernel * in_ch * out_ch,
                "conv{index}: weight tensor has {} entries, expected {kernel}x{kernel}x{in_ch}x{out_ch}",
                w.len()
            );
            assert_eq!(
                b.len(),
                *out_ch,
                "conv{index}: bias vector has {} entries, expected one per output channel ({out_ch})",
                b.len()
            );
        }
        (Layer::Pool { .. }, LayerWeights::None) => {}
        (layer, _) => panic!(
            "{}{index}: weight kind does not match the layer kind",
            layer.kind_str()
        ),
    }
}

impl LayerSim {
    /// Density threshold for the conv sparse activation path and the
    /// sparse accumulator clear: the event-driven walk wins while the
    /// visited positions stay under `fmap / CONV_SPARSE_DENSITY_DIV`;
    /// beyond that the linear channel-major sweep's cache behaviour wins.
    const CONV_SPARSE_DENSITY_DIV: usize = 4;

    pub fn new(
        index: usize,
        layer: Layer,
        lhr: usize,
        mem_blocks: usize,
        penc_width: usize,
        beta: f32,
        theta: f32,
        weights: LayerWeights,
        costs: CostModel,
    ) -> Self {
        validate_weights(index, &layer, &weights);
        let logical = layer.logical_units();
        let nu = NuMap::from_lhr(logical.max(1), lhr.max(1));
        let n_state = layer.output_bits();
        let row_words = match &layer {
            Layer::Fc { n_pre, .. } => *n_pre,
            // conv: one row of K*K*cin coefficients per output channel
            Layer::Conv { in_ch, kernel, .. } => kernel * kernel * in_ch,
            Layer::Pool { .. } => 0,
        };
        let mem = MemoryUnit::new(mem_blocks, nu.units, row_words, logical.max(1));
        let name = format!("{}{}", layer.kind_str(), index);
        let fmap = match &layer {
            Layer::Conv { height, width, .. } => height * width,
            _ => 0,
        };
        let lazy_leak_ok = match (&layer, &weights) {
            (Layer::Conv { .. }, LayerWeights::Conv { b, .. }) => {
                b.iter().all(|&x| x == 0.0) && (0.0..=1.0).contains(&beta) && theta > 0.0
            }
            _ => false,
        };
        LayerSim {
            nu,
            mem,
            penc: Penc::new(penc_width),
            stats: LayerStats::new(name),
            costs,
            lif: LifState::new(
                if layer.is_parametric() { n_state } else { 0 },
                beta,
                theta,
            ),
            acc: vec![0.0; if layer.is_parametric() { n_state } else { 0 }],
            touched: Vec::new(),
            touched_flag: vec![false; if matches!(layer, Layer::Conv { .. }) { n_state } else { 0 }],
            addr_buf: Vec::new(),
            spike_buf: vec![false; n_state],
            synced_steps: vec![0; fmap],
            steps_done: 0,
            hot: Vec::new(),
            hot_scratch: Vec::new(),
            dense_residual: false,
            lazy_leak_ok,
            layer,
            weights,
        }
    }

    /// Cost-only instance: no weights, no membrane/accumulator buffers.
    /// Only `step_cost_only` may be called on it — the activity-driven DSE
    /// path uses this to avoid allocating (and randomly filling) tens of
    /// megabytes per evaluated configuration (EXPERIMENTS.md §Perf #1).
    pub fn new_cost_only(
        index: usize,
        layer: Layer,
        lhr: usize,
        mem_blocks: usize,
        penc_width: usize,
        costs: CostModel,
    ) -> Self {
        let logical = layer.logical_units();
        let nu = NuMap::from_lhr(logical.max(1), lhr.max(1));
        let row_words = match &layer {
            Layer::Fc { n_pre, .. } => *n_pre,
            Layer::Conv { in_ch, kernel, .. } => kernel * kernel * in_ch,
            Layer::Pool { .. } => 0,
        };
        let mem = MemoryUnit::new(mem_blocks, nu.units, row_words, logical.max(1));
        let name = format!("{}{}", layer.kind_str(), index);
        LayerSim {
            nu,
            mem,
            penc: Penc::new(penc_width),
            stats: LayerStats::new(name),
            costs,
            lif: LifState::new(0, 0.0, 1.0),
            acc: Vec::new(),
            touched: Vec::new(),
            touched_flag: Vec::new(),
            addr_buf: Vec::new(),
            spike_buf: Vec::new(),
            synced_steps: Vec::new(),
            steps_done: 0,
            hot: Vec::new(),
            hot_scratch: Vec::new(),
            dense_residual: false,
            lazy_leak_ok: false,
            layer,
            weights: LayerWeights::None,
        }
    }

    /// Zero the functional state (membrane potentials + accumulators) but
    /// keep the accumulated statistics — the per-sample reset the batched
    /// serving workload applies at sample boundaries. Also rewinds the
    /// conv lazy-leak bookkeeping so a fresh sample starts fully synced.
    pub fn reset_state(&mut self) {
        self.lif.reset();
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        self.steps_done = 0;
        self.synced_steps.iter_mut().for_each(|s| *s = 0);
        self.hot.clear();
        self.dense_residual = false;
    }

    pub fn reset(&mut self) {
        self.reset_state();
        self.stats = LayerStats::new(self.stats.name.clone());
    }

    /// Functional step: consume one time step's input spike train, produce
    /// the output train and the cycle breakdown. Allocating wrapper around
    /// [`LayerSim::step_into`], kept for tests/tools; the engine's hot
    /// path writes into a reused buffer instead.
    pub fn step(&mut self, input: &BitVec) -> (BitVec, PhaseCycles) {
        let mut out = BitVec::zeros(0);
        let phases = self.step_into(input, &mut out);
        (out, phases)
    }

    /// Functional step writing the output spike train into `out` (resized
    /// and overwritten in place — no allocation once `out` has grown to
    /// the layer's output width).
    pub fn step_into(&mut self, input: &BitVec, out: &mut BitVec) -> PhaseCycles {
        debug_assert_eq!(input.len(), self.layer.input_bits());
        match self.layer {
            Layer::Fc { .. } => self.step_fc(input, out),
            Layer::Conv { .. } => self.step_conv(input, out),
            Layer::Pool { .. } => self.step_pool(input, out),
        }
    }

    // ---- FC ---------------------------------------------------------------
    fn step_fc(&mut self, input: &BitVec, out: &mut BitVec) -> PhaseCycles {
        let (n_pre, n) = match self.layer {
            Layer::Fc { n_pre, n } => (n_pre, n),
            _ => unreachable!(),
        };
        let mut addrs = std::mem::take(&mut self.addr_buf);
        let (comp_cycles, _chunks_scanned) =
            self.penc.compress_into(input, &self.costs, &mut addrs);
        let s = addrs.len();

        // Accumulate: every logical neuron adds w[a][j] for each spike a.
        let (w, b) = match &self.weights {
            LayerWeights::Fc { w, b } => (w.as_slice(), b.as_slice()),
            _ => panic!("fc layer without fc weights"),
        };
        debug_assert_eq!(w.len(), n_pre * n);
        fc_accumulate(&mut self.acc, w, n, &addrs);

        // Activate: serial LIF pass inside each NU (parallel across NUs).
        let fired = self.lif.activate(&self.acc, b, &mut self.spike_buf);
        if s > 0 {
            // with no input spikes the accumulators were never written, so
            // the dense clear is skipped (values identical either way)
            self.acc.iter_mut().for_each(|a| *a = 0.0);
        }
        out.fill_from_bools(&self.spike_buf[..n]);
        let phases = self.fc_account(s, fired);
        debug_assert_eq!(phases.compress, comp_cycles);
        self.addr_buf = addrs;
        phases
    }

    /// Charge one FC step's cycles and statistics given only its spike
    /// counts. Every FC cost and `LayerStats` field is content-independent
    /// — a pure function of `(s, fired)` and the layer configuration — so
    /// this is shared between the functional `step_fc` above and the
    /// bit-sliced batch kernel's accounting replay
    /// (`sim::batch_kernel`), which must reproduce `PhaseCycles` and
    /// `LayerStats` byte-identically in the per-sample step order.
    pub(crate) fn fc_account(&mut self, s: usize, fired: usize) -> PhaseCycles {
        let (n_pre, n) = match self.layer {
            Layer::Fc { n_pre, n } => (n_pre, n),
            _ => panic!("fc_account on non-fc layer"),
        };
        self.stats.penc_chunks += n_pre.div_ceil(self.penc.width) as u64;
        let stall = self.mem.stall_factor();
        let accum_cycles =
            s as u64 * self.nu.per_unit() as u64 * self.costs.fc_accum * stall;
        self.mem.record_reads((s * n) as u64);
        self.stats.weight_reads += (s * n) as u64;
        self.stats.accum_ops += (s * n) as u64;
        self.stats.membrane_accesses += 2 * n as u64;
        self.stats.activations += n as u64;
        let phases = PhaseCycles {
            compress: self.penc.compress_cost(n_pre, s, &self.costs),
            accumulate: accum_cycles,
            activate: self.nu.per_unit() as u64 * self.costs.act_fc,
            overhead: self.costs.phase_overhead,
        };
        self.stats.add_step(&phases, s, fired);
        phases
    }

    /// Borrowed view of the pieces the bit-sliced batch kernel needs to run
    /// this FC layer's exact arithmetic out-of-band (weights, bias, LIF
    /// parameters). `None` for conv/pool layers — the kernel falls back to
    /// the per-sample engine for those topologies.
    pub(crate) fn fc_view(&self) -> Option<FcView<'_>> {
        match (&self.layer, &self.weights) {
            (Layer::Fc { n_pre, n }, LayerWeights::Fc { w, b }) => Some(FcView {
                n_pre: *n_pre,
                n: *n,
                w,
                b,
                beta: self.lif.beta,
                theta: self.lif.theta,
            }),
            _ => None,
        }
    }

    // ---- CONV ---------------------------------------------------------------
    fn step_conv(&mut self, input: &BitVec, out: &mut BitVec) -> PhaseCycles {
        let (in_ch, out_ch, k, h, w_) = match self.layer {
            Layer::Conv {
                in_ch,
                out_ch,
                kernel,
                height,
                width,
            } => (in_ch, out_ch, kernel, height, width),
            _ => unreachable!(),
        };
        let mut addrs = std::mem::take(&mut self.addr_buf);
        let (comp_cycles, chunks_scanned) =
            self.penc.compress_into(input, &self.costs, &mut addrs);
        let s = addrs.len();
        self.stats.penc_chunks += chunks_scanned;

        let wts = match &self.weights {
            LayerWeights::Conv { w, .. } => w.as_slice(),
            _ => panic!("conv layer without conv weights"),
        };
        let pad = (k - 1) / 2;
        let fmap = h * w_;
        self.touched.clear();

        // Spike -> affected-neuron address extraction + weight accumulation
        // (paper Fig. 5). 1-D address decomposed to (ci, y, x); 'same'
        // padding means output (oc, ny, nx) with ny = y + pad - dy.
        // `taps` counts the kernel taps actually in range — spikes near the
        // feature-map border touch fewer than k*k positions, and the memory
        // traffic counters below must reflect that clipped footprint.
        let mut taps = 0u64;
        for &a in &addrs {
            let a = a as usize;
            let ci = a / fmap;
            let y = (a % fmap) / w_;
            let x = a % w_;
            for dy in 0..k {
                let ny = y + pad;
                if ny < dy {
                    continue;
                }
                let ny = ny - dy;
                if ny >= h {
                    continue;
                }
                for dx in 0..k {
                    let nx = x + pad;
                    if nx < dx {
                        continue;
                    }
                    let nx = nx - dx;
                    if nx >= w_ {
                        continue;
                    }
                    let wbase = ((dy * k + dx) * in_ch + ci) * out_ch;
                    let pos = ny * w_ + nx;
                    for oc in 0..out_ch {
                        self.acc[oc * fmap + pos] += wts[wbase + oc];
                    }
                    taps += 1;
                    if !self.touched_flag[pos] {
                        self.touched_flag[pos] = true;
                        self.touched.push(pos as u32);
                    }
                }
            }
        }
        // CONV accumulate is *independent of LHR*: each NU integrates all
        // its assigned channels in parallel banked membrane BRAMs (the
        // output-channel-wise parallelization of §V-C); the serial walk is
        // over the K x K footprint per spike. LHR therefore trades area,
        // not conv latency — exactly the behaviour of the paper's net-5
        // rows, where raising conv LHR 1 -> 16 leaves latency unchanged.
        let stall = self.mem.stall_factor();
        let accum_cycles = s as u64 * (k * k) as u64 * self.costs.conv_rmw * stall;
        // Memory traffic covers only the in-range taps: the accumulate
        // stage still walks all k*k footprint slots serially (cycles
        // above), but out-of-range taps are masked and issue no weight
        // read / accumulate / membrane RMW — border spikes used to be
        // overcounted here (`s*k*k*out_ch` regardless of clipping), which
        // inflated the energy estimates fed to the DSE.
        let rmw = taps * out_ch as u64;
        self.mem.record_reads(rmw);
        self.stats.weight_reads += rmw;
        self.stats.accum_ops += rmw;
        self.stats.membrane_accesses += 2 * rmw;

        // Activation: touched-set sparse walk or dense channel-major sweep,
        // chosen per step by a density threshold. Both produce spikes,
        // cycles and stats **byte-identical** to the scalar oracle's dense
        // pass (`baselines::scalar`, fuzzed in tests/fuzz_differential.rs).
        // The sparse walk is legal only when a skipped neuron provably
        // cannot fire (`lazy_leak_ok`: zero biases, 0 <= beta <= 1,
        // theta > 0) and no untracked residual membrane sits at or above
        // theta; the leak it defers is replayed one step at a time on the
        // neuron's next visit, reproducing the oracle's f32 sequence.
        let n_out = out_ch * fmap;
        let beta = self.lif.beta;
        let theta = self.lif.theta;
        let use_sparse = self.lazy_leak_ok
            && !self.dense_residual
            && (self.touched.len() + self.hot.len()) * Self::CONV_SPARSE_DENSITY_DIV < fmap;
        let fired = if use_sparse {
            let mut fired = 0usize;
            out.reset(n_out);
            for &pu in &self.touched {
                let p = pu as usize;
                let stale = self.steps_done - self.synced_steps[p];
                let (f, hot) = lazy_visit_pos(
                    &mut self.lif.v,
                    &self.acc,
                    out,
                    p,
                    (fmap, out_ch),
                    (beta, theta),
                    stale,
                );
                fired += f;
                if hot {
                    self.hot_scratch.push(pu);
                }
                self.synced_steps[p] = self.steps_done + 1;
            }
            // residual-hot carryover: positions that can fire without any
            // input this step (soft-reset left some channel at >= theta)
            let prev_hot = std::mem::take(&mut self.hot);
            for &pu in &prev_hot {
                let p = pu as usize;
                if self.touched_flag[p] {
                    continue; // already visited via the touched set
                }
                let stale = self.steps_done - self.synced_steps[p];
                let (f, hot) = lazy_visit_pos(
                    &mut self.lif.v,
                    &self.acc,
                    out,
                    p,
                    (fmap, out_ch),
                    (beta, theta),
                    stale,
                );
                fired += f;
                if hot {
                    self.hot_scratch.push(pu);
                }
                self.synced_steps[p] = self.steps_done + 1;
            }
            // next step's hot set; recycle the old allocation as scratch
            self.hot = std::mem::take(&mut self.hot_scratch);
            self.hot_scratch = prev_hot;
            self.hot_scratch.clear();
            self.dense_residual = false;
            fired
        } else {
            // dense sweep: first bring lazily-skipped positions current
            if self.lazy_leak_ok {
                self.sync_all_positions(fmap, out_ch, beta);
            }
            let b = match &self.weights {
                LayerWeights::Conv { b, .. } => b.as_slice(),
                _ => unreachable!(),
            };
            let mut fired = 0usize;
            let mut residual = false;
            for oc in 0..out_ch {
                // shape validated at construction: exactly one bias per
                // output channel, so no silent zero-fill here
                let bias = b[oc];
                let base = oc * fmap;
                // per-channel slices elide bounds checks in the dense
                // leak+integrate pass (§Perf #3)
                let vs = &mut self.lif.v[base..base + fmap];
                let accs = &self.acc[base..base + fmap];
                let spks = &mut self.spike_buf[base..base + fmap];
                for ((v, &a), sp) in vs.iter_mut().zip(accs).zip(spks.iter_mut()) {
                    let v_new = beta * *v + a + bias;
                    let spike = v_new >= theta;
                    let stored = if spike { v_new - theta } else { v_new };
                    *v = stored;
                    *sp = spike;
                    fired += spike as usize;
                    residual |= stored >= theta;
                }
            }
            if self.lazy_leak_ok {
                let next = self.steps_done + 1;
                self.synced_steps.iter_mut().for_each(|sy| *sy = next);
            }
            self.hot.clear();
            self.dense_residual = residual;
            out.fill_from_bools(&self.spike_buf[..n_out]);
            fired
        };
        self.steps_done += 1;

        // Accumulator clear: only touched positions were ever written, so
        // clear just those while they are sparse; fall back to the linear
        // wipe once the touched set covers a sizable fraction of the fmap.
        if self.touched.len() * Self::CONV_SPARSE_DENSITY_DIV < fmap {
            for &pu in &self.touched {
                let p = pu as usize;
                for oc in 0..out_ch {
                    self.acc[oc * fmap + p] = 0.0;
                }
            }
        } else {
            self.acc.iter_mut().for_each(|a| *a = 0.0);
        }
        let touched_per_ch = self.touched.len() as u64;
        for &pos in &self.touched {
            self.touched_flag[pos as usize] = false;
        }
        // Activation also runs channel-parallel over the touched set; the
        // generated spikes then serialize into the inter-layer buffer.
        let activate_cycles = touched_per_ch * self.costs.act_conv
            + fired as u64 * self.costs.conv_emit;
        self.stats.activations += touched_per_ch * out_ch as u64;

        let phases = PhaseCycles {
            compress: comp_cycles,
            accumulate: accum_cycles,
            activate: activate_cycles,
            overhead: self.costs.phase_overhead,
        };
        self.stats.add_step(&phases, s, fired);
        self.addr_buf = addrs;
        phases
    }

    /// Bring every lazily-skipped feature-map position current before a
    /// dense sweep: replay the pure-leak steps the sparse path deferred,
    /// bit-identical to the oracle's dense updates on untouched, bias-free
    /// positions. No-op when nothing is stale.
    fn sync_all_positions(&mut self, fmap: usize, out_ch: usize, beta: f32) {
        let steps_done = self.steps_done;
        for (p, synced) in self.synced_steps.iter_mut().enumerate() {
            let stale = steps_done - *synced;
            if stale == 0 {
                continue;
            }
            for oc in 0..out_ch {
                let v = &mut self.lif.v[oc * fmap + p];
                for _ in 0..stale {
                    *v = beta * *v + 0.0 + 0.0;
                }
            }
            *synced = steps_done;
        }
    }

    // ---- POOL ---------------------------------------------------------------
    fn step_pool(&mut self, input: &BitVec, out: &mut BitVec) -> PhaseCycles {
        let (ch, size, h, w_) = match self.layer {
            Layer::Pool {
                ch,
                size,
                height,
                width,
            } => (ch, size, height, width),
            _ => unreachable!(),
        };
        let (oh, ow) = (h / size, w_ / size);
        out.reset(ch * oh * ow);
        let mut s_in = 0usize;
        // word-level scan: each spike routes combinationally to its output
        // window; rows/columns beyond the last full window are clipped
        input.for_each_one(|idx| {
            s_in += 1;
            let c = idx / (h * w_);
            let y = (idx % (h * w_)) / w_;
            let x = idx % w_;
            let (py, px) = (y / size, x / size);
            if py < oh && px < ow {
                out.set(c * oh * ow + py * ow + px);
            }
        });
        let fired = out.count_ones();
        let phases = PhaseCycles {
            compress: 0,
            accumulate: 0,
            // OR-gating is combinational; routing each spike to its output
            // window costs pool_per_spike.
            activate: s_in as u64 * self.costs.pool_per_spike,
            overhead: self.costs.phase_overhead,
        };
        self.stats.add_step(&phases, s_in, fired);
        phases
    }

    // ---- activity-driven (cost-only) -----------------------------------------
    /// Charge cycles for a step given only spike counts (no functional
    /// compute). `s_in`/`s_out` come from a calibrated activity model.
    pub fn step_cost_only(&mut self, s_in: usize, s_out: usize) -> PhaseCycles {
        let costs = self.costs.clone();
        let stall = self.mem.stall_factor();
        let phases = match self.layer {
            Layer::Fc { n_pre, n } => {
                self.stats.weight_reads += (s_in * n) as u64;
                self.stats.accum_ops += (s_in * n) as u64;
                self.stats.membrane_accesses += 2 * n as u64;
                self.stats.activations += n as u64;
                self.stats.penc_chunks += n_pre.div_ceil(self.penc.width) as u64;
                PhaseCycles {
                    compress: self.penc.compress_cost(n_pre, s_in, &costs),
                    accumulate: s_in as u64
                        * self.nu.per_unit() as u64
                        * costs.fc_accum
                        * stall,
                    activate: self.nu.per_unit() as u64 * costs.act_fc,
                    overhead: costs.phase_overhead,
                }
            }
            Layer::Conv {
                in_ch,
                out_ch,
                kernel,
                height,
                width,
            } => {
                let bits = in_ch * height * width;
                let fmap = height * width;
                // touched positions per channel: s*k^2 capped by the fmap
                let touched = (s_in * kernel * kernel).min(fmap) as u64;
                // Without spike positions the exact clipped footprint is
                // unknowable; charge the *expected* in-range taps for
                // uniformly placed spikes (exact for the functional path's
                // border clipping on average) instead of the old k*k
                // upper bound that overcounted every border spike.
                let rmw = s_in as u64 * conv_clipped_taps_sum(kernel, height, width)
                    * out_ch as u64
                    / fmap as u64;
                self.stats.weight_reads += rmw;
                self.stats.accum_ops += rmw;
                self.stats.membrane_accesses += 2 * rmw;
                self.stats.activations += touched * out_ch as u64;
                self.stats.penc_chunks += bits.div_ceil(self.penc.width) as u64;
                PhaseCycles {
                    compress: self.penc.compress_cost(bits, s_in, &costs),
                    accumulate: s_in as u64
                        * (kernel * kernel) as u64
                        * costs.conv_rmw
                        * stall,
                    activate: touched * costs.act_conv
                        + s_out as u64 * costs.conv_emit,
                    overhead: costs.phase_overhead,
                }
            }
            Layer::Pool { .. } => PhaseCycles {
                compress: 0,
                accumulate: 0,
                activate: s_in as u64 * costs.pool_per_spike,
                overhead: costs.phase_overhead,
            },
        };
        self.stats.add_step(&phases, s_in, s_out);
        phases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fc_layer(n_pre: usize, n: usize, lhr: usize, w_val: f32) -> LayerSim {
        LayerSim::new(
            0,
            Layer::Fc { n_pre, n },
            lhr,
            0,
            64,
            0.9,
            1.0,
            LayerWeights::Fc {
                w: vec![w_val; n_pre * n],
                b: vec![0.0; n],
            },
            CostModel::default(),
        )
    }

    #[test]
    fn fc_step_counts_cycles_and_fires() {
        let mut l = fc_layer(100, 10, 1, 0.6);
        let mut input = BitVec::zeros(100);
        input.set(3);
        input.set(50);
        // two spikes x 0.6 = 1.2 >= theta => every neuron fires
        let (out, phases) = l.step(&input);
        assert_eq!(out.count_ones(), 10);
        // compress: ceil(100/64)=2 chunks + 2 spikes = 4
        assert_eq!(phases.compress, 4);
        // accumulate: 2 spikes x 1 neuron/NU x fc_accum(2) = 4
        assert_eq!(phases.accumulate, 4);
        assert_eq!(phases.activate, 1);
        assert_eq!(l.stats.weight_reads, 20);
    }

    #[test]
    fn fc_lhr_scales_accumulate_serially() {
        let mut input = BitVec::zeros(100);
        for i in 0..10 {
            input.set(i * 7);
        }
        let mut l1 = fc_layer(100, 64, 1, 0.0);
        let mut l8 = fc_layer(100, 64, 8, 0.0);
        let (_, p1) = l1.step(&input);
        let (_, p8) = l8.step(&input);
        assert_eq!(p8.accumulate, 8 * p1.accumulate);
        assert_eq!(p8.activate, 8 * p1.activate);
        // compression is independent of LHR
        assert_eq!(p8.compress, p1.compress);
    }

    #[test]
    fn fc_membrane_carries_over_steps() {
        let mut l = fc_layer(10, 1, 1, 0.4);
        let mut input = BitVec::zeros(10);
        input.set(0);
        let (out1, _) = l.step(&input); // v = 0.4
        assert_eq!(out1.count_ones(), 0);
        let (out2, _) = l.step(&input); // v = 0.36 + 0.4 = 0.76
        assert_eq!(out2.count_ones(), 0);
        let (out3, _) = l.step(&input); // v = 0.684 + 0.4 = 1.084 -> fire
        assert_eq!(out3.count_ones(), 1);
    }

    #[test]
    fn pool_or_gates_windows() {
        let mut l = LayerSim::new(
            1,
            Layer::Pool {
                ch: 1,
                size: 2,
                height: 4,
                width: 4,
            },
            1,
            0,
            64,
            0.9,
            1.0,
            LayerWeights::None,
            CostModel::default(),
        );
        let mut input = BitVec::zeros(16);
        input.set(0); // (0,0) -> window (0,0)
        input.set(5); // (1,1) -> window (0,0) (OR'd)
        input.set(15); // (3,3) -> window (1,1)
        let (out, phases) = l.step(&input);
        assert_eq!(out.count_ones(), 2);
        assert!(out.get(0) && out.get(3));
        assert_eq!(phases.activate, 3);
    }

    #[test]
    fn conv_accumulates_neighborhood() {
        // 1 input channel 4x4, 1 output channel, k=3, all weights 1.0,
        // theta high so nothing fires; check touched accounting via cycles.
        let mut l = LayerSim::new(
            0,
            Layer::Conv {
                in_ch: 1,
                out_ch: 1,
                kernel: 3,
                height: 4,
                width: 4,
            },
            1,
            0,
            64,
            0.9,
            100.0,
            LayerWeights::Conv {
                w: vec![1.0; 9],
                b: vec![0.0],
            },
            CostModel::default(),
        );
        let mut input = BitVec::zeros(16);
        input.set(5); // (y=1, x=1): all 9 neighbors in range
        let (out, phases) = l.step(&input);
        assert_eq!(out.count_ones(), 0);
        // accumulate: 1 spike x 1 ch/NU x 9 x conv_rmw(3) = 27
        assert_eq!(phases.accumulate, 27);
        // 9 touched positions x act_conv(2)
        assert_eq!(phases.activate, 18);
        // membrane got exactly 9 ones
        assert_eq!(l.lif.v.iter().filter(|&&v| v > 0.5).count(), 9);
    }

    #[test]
    fn conv_corner_clips() {
        let mut l = LayerSim::new(
            0,
            Layer::Conv {
                in_ch: 1,
                out_ch: 1,
                kernel: 3,
                height: 4,
                width: 4,
            },
            1,
            0,
            64,
            0.9,
            100.0,
            LayerWeights::Conv {
                w: vec![1.0; 9],
                b: vec![0.0],
            },
            CostModel::default(),
        );
        let mut input = BitVec::zeros(16);
        input.set(0); // corner: only 4 neighbors in range
        let (_, phases) = l.step(&input);
        assert_eq!(phases.activate, 8); // 4 touched x 2
        assert_eq!(l.lif.v.iter().filter(|&&v| v > 0.5).count(), 4);
    }

    fn conv_4x4(out_ch: usize) -> LayerSim {
        LayerSim::new(
            0,
            Layer::Conv {
                in_ch: 1,
                out_ch,
                kernel: 3,
                height: 4,
                width: 4,
            },
            1,
            0,
            64,
            0.9,
            100.0,
            LayerWeights::Conv {
                w: vec![1.0; 9 * out_ch],
                b: vec![0.0; out_ch],
            },
            CostModel::default(),
        )
    }

    #[test]
    #[should_panic(expected = "bias vector has 1 entries, expected one per output channel (2)")]
    fn conv_short_bias_rejected_at_construction() {
        // regression: a short conv bias used to be silently zero-filled in
        // the activation loop instead of failing when the layer is built
        let _ = LayerSim::new(
            0,
            Layer::Conv {
                in_ch: 1,
                out_ch: 2,
                kernel: 3,
                height: 4,
                width: 4,
            },
            1,
            0,
            64,
            0.9,
            1.0,
            LayerWeights::Conv {
                w: vec![1.0; 18],
                b: vec![0.0; 1],
            },
            CostModel::default(),
        );
    }

    #[test]
    #[should_panic(expected = "bias vector has 3 entries, expected one per neuron (10)")]
    fn fc_short_bias_rejected_at_construction() {
        let _ = LayerSim::new(
            0,
            Layer::Fc { n_pre: 4, n: 10 },
            1,
            0,
            64,
            0.9,
            1.0,
            LayerWeights::Fc {
                w: vec![0.0; 40],
                b: vec![0.0; 3],
            },
            CostModel::default(),
        );
    }

    #[test]
    #[should_panic(expected = "weight matrix has 39 entries, expected 4x10")]
    fn fc_wrong_weight_count_rejected_at_construction() {
        let _ = LayerSim::new(
            0,
            Layer::Fc { n_pre: 4, n: 10 },
            1,
            0,
            64,
            0.9,
            1.0,
            LayerWeights::Fc {
                w: vec![0.0; 39],
                b: vec![0.0; 10],
            },
            CostModel::default(),
        );
    }

    #[test]
    fn conv_border_spike_counts_clipped_footprint() {
        // regression: border spikes used to charge the full k*k*out_ch
        // upper bound to weight_reads/accum_ops/membrane_accesses
        let mut l = conv_4x4(2);
        let mut input = BitVec::zeros(16);
        input.set(0); // corner: only a 2x2 window of the 3x3 kernel lands
        let _ = l.step(&input);
        assert_eq!(l.stats.weight_reads, 4 * 2, "4 taps x 2 channels");
        assert_eq!(l.stats.accum_ops, 4 * 2);
        assert_eq!(l.stats.membrane_accesses, 2 * 4 * 2);

        // interior spike still counts the full footprint
        let mut l = conv_4x4(2);
        let mut input = BitVec::zeros(16);
        input.set(5); // (y=1, x=1): all 9 taps in range
        let _ = l.step(&input);
        assert_eq!(l.stats.weight_reads, 9 * 2);
        assert_eq!(l.stats.accum_ops, 9 * 2);
        assert_eq!(l.stats.membrane_accesses, 2 * 9 * 2);

        // edge (non-corner) spike: 3x2 window
        let mut l = conv_4x4(1);
        let mut input = BitVec::zeros(16);
        input.set(4); // (y=1, x=0)
        let _ = l.step(&input);
        assert_eq!(l.stats.weight_reads, 6);
    }

    #[test]
    fn clipped_taps_sum_matches_bruteforce() {
        for (k, h, w) in [(3usize, 4usize, 4usize), (3, 5, 7), (5, 6, 6), (1, 4, 4)] {
            let pad = (k - 1) / 2;
            let mut brute = 0u64;
            for y in 0..h {
                for x in 0..w {
                    for dy in 0..k {
                        for dx in 0..k {
                            let ny = y + pad;
                            let nx = x + pad;
                            if ny >= dy && ny - dy < h && nx >= dx && nx - dx < w {
                                brute += 1;
                            }
                        }
                    }
                }
            }
            assert_eq!(
                conv_clipped_taps_sum(k, h, w),
                brute,
                "k={k} h={h} w={w}"
            );
        }
    }

    #[test]
    fn cost_only_conv_charges_expected_clipped_footprint() {
        // 3x3 kernel over 4x4: taps sum = 10*10 = 100 across 16 positions
        let mut l = LayerSim::new_cost_only(
            0,
            Layer::Conv {
                in_ch: 1,
                out_ch: 2,
                kernel: 3,
                height: 4,
                width: 4,
            },
            1,
            0,
            64,
            CostModel::default(),
        );
        let _ = l.step_cost_only(16, 0);
        // 16 spikes x (100/16 mean taps) x 2 channels = 200 (integer math)
        assert_eq!(l.stats.weight_reads, 16 * 100 * 2 / 16);
        assert!(l.stats.weight_reads < (16 * 9 * 2) as u64, "below the old upper bound");
    }

    #[test]
    fn pool_non_divisible_dims_clip_partial_windows() {
        // 5x5 input, 2x2 windows: output is 2x2 and the 5th row/column
        // (the `py < oh` / `px < ow` clip branch) is dropped entirely.
        let mut l = LayerSim::new(
            1,
            Layer::Pool {
                ch: 1,
                size: 2,
                height: 5,
                width: 5,
            },
            1,
            0,
            64,
            0.9,
            1.0,
            LayerWeights::None,
            CostModel::default(),
        );
        let mut input = BitVec::zeros(25);
        input.set(0); // (0,0) -> window (0,0)
        input.set(4); // (0,4): px = 2 clipped
        input.set(23); // (4,3): py = 2 clipped
        input.set(24); // (4,4): both clipped
        let (out, phases) = l.step(&input);
        assert_eq!(out.len(), 4);
        assert_eq!(out.count_ones(), 1);
        assert!(out.get(0));
        // clipped spikes still cost routing cycles: 4 x pool_per_spike
        assert_eq!(phases.activate, 4 * CostModel::default().pool_per_spike);
        assert_eq!(phases.compress, 0);
        assert_eq!(phases.accumulate, 0);
        assert_eq!(l.stats.in_spikes, 4);
        assert_eq!(l.stats.out_spikes, 1);
        assert_eq!(l.stats.max_shift_depth, 4);
    }

    #[test]
    fn pool_all_spikes_input_saturates_every_window() {
        for (h, w, size) in [(5usize, 5usize, 2usize), (6, 4, 3), (7, 7, 2)] {
            let mut l = LayerSim::new(
                1,
                Layer::Pool {
                    ch: 2,
                    size,
                    height: h,
                    width: w,
                },
                1,
                0,
                64,
                0.9,
                1.0,
                LayerWeights::None,
                CostModel::default(),
            );
            let bits = 2 * h * w;
            let input = BitVec::from_bools(&vec![true; bits]);
            let (out, phases) = l.step(&input);
            let (oh, ow) = (h / size, w / size);
            assert_eq!(out.len(), 2 * oh * ow, "h={h} w={w} size={size}");
            // every window holds at least one spike -> all outputs fire
            assert_eq!(out.count_ones(), 2 * oh * ow, "h={h} w={w} size={size}");
            // cycle accounting charges every input spike, clipped or not
            assert_eq!(
                phases.activate,
                bits as u64 * CostModel::default().pool_per_spike
            );
            assert_eq!(l.stats.in_spikes, bits as u64);
            assert_eq!(l.stats.out_spikes, (2 * oh * ow) as u64);
        }
    }

    /// Drive the optimized layer and the preserved scalar oracle through
    /// the same input sequence; outputs, phases, and stats must match
    /// byte-for-byte at every step.
    fn assert_layer_matches_oracle(
        layer: Layer,
        weights: LayerWeights,
        beta: f32,
        theta: f32,
        inputs: &[BitVec],
    ) {
        use crate::baselines::scalar::ScalarLayerSim;
        let mut fast = LayerSim::new(
            0,
            layer.clone(),
            1,
            0,
            64,
            beta,
            theta,
            weights.clone(),
            CostModel::default(),
        );
        let mut oracle =
            ScalarLayerSim::new(0, layer, 1, 0, 64, beta, theta, weights, CostModel::default());
        for (t, input) in inputs.iter().enumerate() {
            let (fo, fp) = fast.step(input);
            let (oo, op) = oracle.step(input);
            assert_eq!(fo, oo, "step {t}: output spikes diverge");
            assert_eq!(fp, op, "step {t}: phase cycles diverge");
        }
        assert_eq!(
            format!("{:?}", fast.stats),
            format!("{:?}", oracle.stats),
            "stats diverge"
        );
    }

    fn conv_8x8_layer(out_ch: usize) -> Layer {
        Layer::Conv {
            in_ch: 1,
            out_ch,
            kernel: 3,
            height: 8,
            width: 8,
        }
    }

    fn conv_weights(out_ch: usize, scale: f32, bias: f32, seed: u64) -> LayerWeights {
        let mut rng = crate::util::rng::Rng::new(seed);
        LayerWeights::Conv {
            w: (0..9 * out_ch).map(|_| (rng.normal() as f32) * scale).collect(),
            b: vec![bias; out_ch],
        }
    }

    #[test]
    fn conv_sparse_path_matches_oracle_on_sparse_steps() {
        // single-spike steps keep the touched set far below the density
        // threshold, so the lazy touched-set walk runs every step
        let mut inputs = Vec::new();
        for t in 0..10usize {
            let mut b = BitVec::zeros(64);
            b.set((t * 13 + 5) % 64);
            inputs.push(b);
        }
        inputs.push(BitVec::zeros(64)); // zero-activity step
        inputs.push(BitVec::zeros(64));
        let mut tail = BitVec::zeros(64);
        tail.set(0);
        inputs.push(tail); // replay after two fully skipped steps
        assert_layer_matches_oracle(
            conv_8x8_layer(3),
            conv_weights(3, 0.9, 0.0, 11),
            0.9,
            1.0,
            &inputs,
        );
    }

    #[test]
    fn conv_sparse_path_tracks_residual_hot_neurons() {
        // large weights + low theta leave soft-reset residuals >= theta,
        // which must keep firing with no input (the hot carryover set)
        let mut inputs = Vec::new();
        let mut burst = BitVec::zeros(64);
        burst.set(27);
        burst.set(28);
        inputs.push(burst);
        for _ in 0..6 {
            inputs.push(BitVec::zeros(64));
        }
        assert_layer_matches_oracle(
            conv_8x8_layer(2),
            conv_weights(2, 3.0, 0.0, 7),
            0.95,
            0.3,
            &inputs,
        );
    }

    #[test]
    fn conv_alternating_dense_and_sparse_steps_match_oracle() {
        // all-ones steps force the dense sweep; single-spike steps drop
        // back to the sparse walk — the sync/fill handoff between the two
        // paths must replay deferred leak exactly
        let dense = BitVec::from_bools(&[true; 64]);
        let mut sparse = BitVec::zeros(64);
        sparse.set(37);
        let inputs = vec![
            sparse.clone(),
            dense.clone(),
            sparse.clone(),
            BitVec::zeros(64),
            dense,
            BitVec::zeros(64),
            sparse,
        ];
        assert_layer_matches_oracle(
            conv_8x8_layer(2),
            conv_weights(2, 0.8, 0.0, 23),
            0.9,
            1.0,
            &inputs,
        );
    }

    #[test]
    fn conv_nonzero_bias_falls_back_to_dense_and_matches_oracle() {
        // a bias can fire untouched neurons, so the sparse walk is illegal;
        // the layer must take the dense sweep and still match the oracle
        let mut inputs = vec![BitVec::zeros(64); 4];
        inputs[0].set(9);
        inputs[2].set(44);
        assert_layer_matches_oracle(
            conv_8x8_layer(2),
            conv_weights(2, 0.7, 0.4, 3),
            0.9,
            1.0,
            &inputs,
        );
    }

    #[test]
    fn cost_only_matches_functional_fc_cycles() {
        let mut input = BitVec::zeros(100);
        for i in [1, 9, 33, 64, 99] {
            input.set(i);
        }
        let mut f = fc_layer(100, 64, 4, 0.0);
        let (_, pf) = f.step(&input);
        let mut c = fc_layer(100, 64, 4, 0.0);
        let pc = c.step_cost_only(5, 0);
        assert_eq!(pf.total(), pc.total());
    }
}
