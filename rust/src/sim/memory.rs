//! Memory Unit model (paper §V-D).
//!
//! Synapse weights live in block RAMs; `mem_blocks` configures how many
//! physical blocks a layer gets, and the mapping logic arbitrates multiple
//! hardware neurons (NUs) sharing one block. Block depth is
//! `M x SIZE` where `M` is neurons per block and `SIZE` the pre-synaptic
//! layer size. Fewer blocks than NUs serializes weight reads — the
//! `stall_factor` the accumulate phase multiplies into its cycle count.

/// Memory allocation for one layer.
#[derive(Debug, Clone)]
pub struct MemoryUnit {
    /// Physical memory blocks allocated.
    pub n_blocks: usize,
    /// Hardware neural units that read from the blocks.
    pub n_readers: usize,
    /// Pre-synaptic layer size (words per logical neuron row).
    pub row_words: usize,
    /// Logical neurons whose weights are stored.
    pub n_neurons: usize,
    /// Running access counters (for the energy model).
    pub reads: u64,
    pub writes: u64,
}

impl MemoryUnit {
    /// `n_blocks = 0` means auto: one block per reader (no contention),
    /// the hardware generator's default.
    pub fn new(n_blocks: usize, n_readers: usize, row_words: usize, n_neurons: usize) -> Self {
        let n_blocks = if n_blocks == 0 { n_readers.max(1) } else { n_blocks };
        MemoryUnit {
            n_blocks,
            n_readers: n_readers.max(1),
            row_words,
            n_neurons,
            reads: 0,
            writes: 0,
        }
    }

    /// How many read cycles a 1-cycle parallel read actually takes when
    /// blocks are shared: ceil(readers / blocks).
    pub fn stall_factor(&self) -> u64 {
        self.n_readers.div_ceil(self.n_blocks) as u64
    }

    /// Neurons mapped to each block (the `M` in the paper's depth formula).
    pub fn neurons_per_block(&self) -> usize {
        self.n_neurons.div_ceil(self.n_blocks)
    }

    /// Block depth in 32-bit words: M x SIZE.
    pub fn block_depth(&self) -> usize {
        self.neurons_per_block() * self.row_words
    }

    /// 36Kb BRAM primitives needed across all blocks (32-bit words).
    pub fn bram_36k(&self) -> usize {
        let bits_per_block = self.block_depth() * 32;
        self.n_blocks * bits_per_block.div_ceil(36 * 1024)
    }

    pub fn record_reads(&mut self, n: u64) {
        self.reads += n;
    }
    pub fn record_writes(&mut self, n: u64) {
        self.writes += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn auto_allocation_matches_readers() {
        let m = MemoryUnit::new(0, 8, 784, 512);
        assert_eq!(m.n_blocks, 8);
        assert_eq!(m.stall_factor(), 1);
        assert_eq!(m.neurons_per_block(), 64);
        assert_eq!(m.block_depth(), 64 * 784);
    }

    #[test]
    fn sharing_stalls() {
        let m = MemoryUnit::new(2, 8, 100, 64);
        assert_eq!(m.stall_factor(), 4);
        let m = MemoryUnit::new(3, 8, 100, 64);
        assert_eq!(m.stall_factor(), 3);
    }

    #[test]
    fn bram_counts() {
        // 512 neurons x 784 weights x 32b = 12.8 Mb => ~357 BRAM36
        let m = MemoryUnit::new(0, 1, 784, 512);
        let total_bits: usize = 512 * 784 * 32;
        assert_eq!(m.bram_36k(), total_bits.div_ceil(36 * 1024));
    }

    #[test]
    fn prop_stall_and_depth_invariants() {
        prop_check(256, 0x3E3, |g| {
            let readers = g.usize_in(1, 128);
            let blocks = g.usize_in(0, 64);
            let neurons = g.usize_in(1, 2048);
            let row = g.usize_in(1, 2048);
            let m = MemoryUnit::new(blocks, readers, row, neurons);
            if m.stall_factor() < 1 {
                return Err("stall < 1".into());
            }
            // enough capacity for every neuron row
            if m.n_blocks * m.neurons_per_block() < neurons {
                return Err("blocks don't cover all neurons".into());
            }
            // more blocks never increases stall
            let m2 = MemoryUnit::new(m.n_blocks + 1, readers, row, neurons);
            if m2.stall_factor() > m.stall_factor() {
                return Err("stall increased with more blocks".into());
            }
            Ok(())
        });
    }
}
